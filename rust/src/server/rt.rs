//! Real-time serving loop: drives the *same* [`EngineCore`] the DES
//! figure harnesses run — by default `agent-xpu` with its dual queues,
//! kernel-level preemption, decode batching, backfill, and memory
//! governor — against a wall clock ([`EngineClock::wall`]).
//!
//! There is no scheduling policy in this file.  The loop only moves
//! bytes: channel messages in ([`RtMsg`]), engine events out
//! ([`TokenEvent`]).  The policy is selected *by name* from the
//! engine registry (`agent-xpu serve --policy`), so any registered
//! scheduler — `deadline`, a baseline, a future policy — serves the
//! same wire protocol.  Scheduler knobs (`b_max`, `session_capacity`,
//! preemption/backfill switches, …) come from the caller's
//! [`SchedulerConfig`] — the same configuration the simulated
//! coordinator honors.
//!
//! Sessions: a request carrying a `session` tag maps to a flow id; the
//! engine's session pool retains the conversation KV after completion,
//! and the session's next call prefills only the tokens beyond the
//! retained prefix (`done.cached_prefix` reports the reuse).  Retention
//! is bounded by `SchedulerConfig::session_capacity` and shed LRU-first
//! by the memory governor, exactly as in simulation.
//!
//! Overload safety (DESIGN.md §7): the intake channel is bounded by
//! [`OverloadConfig::max_queue_depth`], every submission passes the
//! [`OverloadGate`] (full queue → [`TokenEvent::Rejected`], or a
//! reactive arrival displaces the newest queued proactive request),
//! and each step re-evaluates the policy's
//! [`EngineCore::overload_response`]: pause proactive intake, cancel
//! queued proactive work ([`TokenEvent::Shed`]), or preempt-and-park
//! running proactive decodes — parked turns resume automatically (same
//! generation id, already-streamed tokens suppressed) once the
//! pressure clears.
//!
//! Crash recovery: with a journal attached, every admitted turn is
//! durable *before* its `accepted` frame goes out, terminals
//! (done / cancelled / shed) are appended as they happen, and session
//! bindings ride along.  A restarted server replays the journal:
//! live turns resubmit (cache-cold re-prefill), session flow ids and
//! turn indices survive, and the generation-id counter restarts above
//! everything ever journaled.  The invariant: **no admitted turn is
//! silently dropped** — it completes, cancels, sheds with a frame, or
//! survives restart.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender, SyncSender, TryRecvError, sync_channel};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use anyhow::Result;

use crate::config::{OverloadConfig, SchedulerConfig, SocConfig};
use crate::engine::{
    EngineClock, EngineCore, EngineEvent, ExecBridge, ShedLevel, registry,
};
use crate::metrics::ReportAccumulator;
use crate::server::journal::{BindRec, Journal, Record, SubmitRec};
use crate::server::overload::{AdmissionDecision, OverloadGate};
use crate::workload::{FlowBinding, NodeKind, Priority, ReqId, Request};

/// Poison-safe lock: a panic while holding the stats (or writer) mutex
/// must not take the whole server down with it — the protected data is
/// a counter block (or an output stream), never left mid-invariant.
pub(crate) fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Intake-channel bound when admission control is disabled
/// (`max_queue_depth = 0`): the channel still must not be unbounded.
const INTAKE_FALLBACK_BOUND: usize = 1024;

/// Max session *tags* remembered by the server.  Tags arrive from
/// clients, so the map must be bounded for a long-lived server; when
/// it overflows, the oldest tag is forgotten — that session's next
/// call simply starts cold (its retained KV ages out of the engine's
/// LRU-bounded pool on its own).
const SESSION_TAGS_MAX: usize = 1024;

/// Generation ids remembered per tag for `deps` resolution (a DAG edge
/// can only reference a recent call of the same session).
const SESSION_DEPS_MAX: usize = 64;

/// Per-tag session state: a stable flow id, the number of calls seen
/// (the next node index), and a bounded map from generation id to node
/// index so clients can express DAG dependencies between their calls.
#[derive(Default)]
struct SessionMeta {
    flow_id: u64,
    calls: usize,
    /// generation id → node (turn) index within the flow.
    turn_of: BTreeMap<u64, usize>,
}

/// Bounded session-tag registry: maps client tags to stable flow ids
/// and counts the calls seen per tag (the flow node index).  Ids are
/// monotonic (never reused), so a forgotten tag can never alias
/// another session's retained cache.
#[derive(Default)]
struct SessionRegistry {
    ids: HashMap<String, SessionMeta>,
    order: VecDeque<String>,
    next: u64,
}

impl SessionRegistry {
    /// Resolve a tag to `(flow_id, turn_idx)` for the call `req_id`,
    /// registering the tag if new; evicts the oldest tag beyond
    /// `SESSION_TAGS_MAX` and the oldest remembered generation ids
    /// beyond `SESSION_DEPS_MAX`.
    fn resolve(&mut self, tag: &str, req_id: u64) -> (u64, usize) {
        if let Some(e) = self.ids.get_mut(tag) {
            e.calls += 1;
            let idx = e.calls;
            e.turn_of.insert(req_id, idx);
            while e.turn_of.len() > SESSION_DEPS_MAX {
                let _ = e.turn_of.pop_first();
            }
            return (e.flow_id, idx);
        }
        let sid = self.next;
        self.next += 1;
        let mut meta = SessionMeta { flow_id: sid, calls: 0, turn_of: BTreeMap::new() };
        meta.turn_of.insert(req_id, 0);
        self.ids.insert(tag.to_string(), meta);
        self.order.push_back(tag.to_string());
        while self.order.len() > SESSION_TAGS_MAX {
            if let Some(old) = self.order.pop_front() {
                self.ids.remove(&old);
            }
        }
        (sid, 0)
    }

    /// Map generation ids to node indices within `tag`'s flow; unknown
    /// (or forgotten) ids are dropped — the submission merely waits on
    /// fewer predecessors.
    fn resolve_deps(&self, tag: &str, deps: &[u64]) -> Vec<usize> {
        let Some(e) = self.ids.get(tag) else { return vec![] };
        let mut out: Vec<usize> = deps
            .iter()
            .filter_map(|id| e.turn_of.get(id).copied())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// The already-assigned `(flow_id, turn_idx)` of a journaled call
    /// (replay must not re-`resolve`, which would mint a new turn).
    fn lookup(&self, tag: &str, req_id: u64) -> Option<(u64, usize)> {
        self.ids
            .get(tag)
            .and_then(|e| e.turn_of.get(&req_id).map(|idx| (e.flow_id, *idx)))
    }

    /// Reinstall a journaled binding (replay path).  Ids stay
    /// monotonic: the mint counter restarts above every restored flow.
    fn restore(&mut self, b: &BindRec) {
        let mut meta =
            SessionMeta { flow_id: b.flow_id, calls: b.calls, turn_of: BTreeMap::new() };
        for (id, idx) in &b.turn_of {
            meta.turn_of.insert(*id, *idx);
        }
        if !self.ids.contains_key(&b.tag) {
            self.order.push_back(b.tag.clone());
        }
        self.ids.insert(b.tag.clone(), meta);
        self.next = self.next.max(b.flow_id + 1);
    }

    /// The tag's current binding as a journal record.
    fn snapshot(&self, tag: &str) -> Option<BindRec> {
        self.ids.get(tag).map(|e| BindRec {
            tag: tag.to_string(),
            flow_id: e.flow_id,
            calls: e.calls,
            turn_of: e.turn_of.iter().map(|(id, idx)| (*id, *idx)).collect(),
        })
    }

    #[cfg(test)]
    fn get(&self, tag: &str) -> Option<u64> {
        self.ids.get(tag).map(|e| e.flow_id)
    }
}

/// A request submitted to the real-time serving loop.
pub struct RtRequest {
    pub id: ReqId,
    pub priority: Priority,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Session tag: calls sharing a tag reuse the retained KV of the
    /// previous call's conversation (`None` = single-shot).
    pub session: Option<String>,
    /// DAG predecessors within the same session: generation ids this
    /// call must wait for (fan-out/join workflows over the wire).
    /// Empty = the implicit linear chain (wait for the previous call).
    pub deps: Vec<u64>,
    /// Streamed token events land here.
    pub events: Sender<TokenEvent>,
}

/// Control messages into the serving loop.
pub enum RtMsg {
    Submit(RtRequest),
    /// Abort an in-flight generation; its KV is freed and the client
    /// receives a terminal [`TokenEvent::Cancelled`].
    Cancel(ReqId),
}

/// Streamed output.
#[derive(Debug, Clone, PartialEq)]
pub enum TokenEvent {
    Accepted { id: ReqId },
    Token { id: ReqId, token: i32, n: usize },
    Done {
        id: ReqId,
        ttft_ms: f64,
        total_ms: f64,
        tokens: Vec<i32>,
        /// Prompt tokens served from the session cache (0 = no reuse).
        cached_prefix: usize,
    },
    /// Terminal frame of a cancelled generation.
    Cancelled { id: ReqId },
    /// Terminal: refused at admission (queue full with nothing to
    /// displace, live-flow budget exhausted, or proactive intake
    /// paused).  Retry after the hint.
    Rejected { id: ReqId, retry_after_ms: f64 },
    /// Terminal: this queued proactive generation was shed (or
    /// displaced by a reactive arrival) to protect reactive latency.
    /// Resubmit after the hint.
    Shed { id: ReqId, retry_after_ms: f64 },
    Error { id: ReqId, message: String },
}

/// Streaming state of one live subscription.
struct Sub {
    tx: Sender<TokenEvent>,
    /// Re-emitted tokens to swallow after a park/resume cycle (the
    /// client already streamed them).
    skip: usize,
    /// Tokens the client has actually received.
    emitted: usize,
}

/// Everything needed to resubmit a parked proactive generation.
#[derive(Clone)]
struct ProactiveCtx {
    prompt: Vec<i32>,
    max_new_tokens: usize,
    session: Option<String>,
    flow: Option<FlowBinding>,
}

/// A preempted-and-parked proactive generation awaiting resume.
struct ParkedReq {
    tx: Option<Sender<TokenEvent>>,
    ctx: ProactiveCtx,
    emitted: usize,
}

/// The real-time serving loop.  Owns the engine core (and through it
/// the PJRT runtime); consumes [`RtMsg`]s from a channel until it
/// closes and all work drains.
pub struct RtScheduler {
    core: Box<dyn EngineCore + Send>,
    stats: Arc<Mutex<ReportAccumulator>>,
    gate: OverloadGate,
    journal: Option<Journal>,
    registry: SessionRegistry,
    subs: HashMap<ReqId, Sub>,
    /// Proactive submissions kept resubmittable for park/resume.
    ctx: HashMap<ReqId, ProactiveCtx>,
    /// Parked generations, resumed oldest-id first.
    parked: BTreeMap<ReqId, ParkedReq>,
    /// Victims whose upcoming `Cancelled` event is a shed, not a
    /// client cancel.
    shedding: HashSet<ReqId>,
    /// Accepted frames held back until the journal batch is durable.
    pending_acks: Vec<ReqId>,
    /// Journal-recovered turns to resubmit at serve start.
    recovered: Vec<(SubmitRec, Option<FlowBinding>)>,
    served: u64,
}

impl RtScheduler {
    /// Build the serving loop around the default coordinator policy
    /// (`agent-xpu`): real-compute when the bridge carries a PJRT
    /// executor, timing bridge otherwise.  `sched` is honored wholesale
    /// — `b_max`, `session_capacity`, preemption/backfill/
    /// disaggregation switches.
    pub fn new(bridge: Arc<ExecBridge>, soc: SocConfig, sched: SchedulerConfig) -> Self {
        Self::new_with_policy(bridge, soc, sched, "agent-xpu")
            .expect("the default policy is always registered")
    }

    /// Like [`RtScheduler::new`], but serving any policy registered in
    /// `engine::registry` (the `serve --policy` path).  Fails on an
    /// unknown policy name.
    pub fn new_with_policy(
        bridge: Arc<ExecBridge>,
        soc: SocConfig,
        sched: SchedulerConfig,
        policy: &str,
    ) -> Result<Self> {
        Self::new_full(bridge, soc, sched, policy, OverloadConfig::default(), None)
            .map(|(s, _)| s)
    }

    /// Full-control constructor: overload knobs plus an optional
    /// write-ahead journal.  Opening an existing journal replays it —
    /// live turns resubmit at serve start, session bindings reinstall,
    /// and the returned floor is one past the highest generation id
    /// ever journaled (the UDS layer starts its counter there so ids
    /// never repeat across restarts).
    pub fn new_full(
        bridge: Arc<ExecBridge>,
        soc: SocConfig,
        sched: SchedulerConfig,
        policy: &str,
        overload: OverloadConfig,
        journal: Option<PathBuf>,
    ) -> Result<(Self, u64)> {
        let core: Box<dyn EngineCore + Send> = match bridge.executor() {
            Some(exec) => registry::build_real(policy, exec, soc, sched)?,
            None => registry::build(policy, bridge.geo.clone(), soc, sched)?,
        };
        let mut registry = SessionRegistry::default();
        let mut recovered = vec![];
        let mut next_id_floor = 1u64;
        let mut stats = ReportAccumulator::new();
        let journal = match journal {
            None => None,
            Some(path) => {
                let (j, replay) = Journal::open(&path, overload.fsync_every.max(1))?;
                for b in &replay.bindings {
                    registry.restore(b);
                }
                // Resubmission plan for the surviving turns: bindings
                // come from the journal (never re-minted), and deps are
                // narrowed to turns that also survived — everything
                // else already completed before the crash, so waiting
                // on it would deadlock.  Empty survivors chain linearly
                // within their tag; a turn with no surviving
                // predecessor gets the explicit no-predecessors form
                // (its own index).
                let pending_ids: HashSet<u64> =
                    replay.pending.iter().map(|s| s.id).collect();
                let mut last_turn: HashMap<String, usize> = HashMap::new();
                for s in &replay.pending {
                    let flow = s.session.as_ref().map(|tag| {
                        let (flow_id, turn_idx) = registry
                            .lookup(tag, s.id)
                            .unwrap_or_else(|| registry.resolve(tag, s.id));
                        let mut deps: Vec<usize> = s
                            .deps
                            .iter()
                            .filter(|d| pending_ids.contains(d))
                            .filter_map(|d| registry.lookup(tag, *d).map(|(_, i)| i))
                            .collect();
                        deps.sort_unstable();
                        deps.dedup();
                        if deps.is_empty() {
                            deps = vec![*last_turn.get(tag.as_str()).unwrap_or(&turn_idx)];
                        }
                        last_turn.insert(tag.clone(), turn_idx);
                        FlowBinding {
                            flow_id,
                            turn_idx,
                            total_turns: usize::MAX,
                            think_time_us: 0.0,
                            delta_start: 0,
                            deps,
                            node: NodeKind::Llm,
                            crit_path: 1,
                        }
                    });
                    recovered.push((s.clone(), flow));
                }
                stats.recovered = recovered.len();
                next_id_floor = replay.max_req_id + 1;
                Some(j)
            }
        };
        Ok((
            Self {
                core,
                stats: Arc::new(Mutex::new(stats)),
                gate: OverloadGate::new(overload),
                journal,
                registry,
                subs: HashMap::new(),
                ctx: HashMap::new(),
                parked: BTreeMap::new(),
                shedding: HashSet::new(),
                pending_acks: vec![],
                recovered,
                served: 0,
            },
            next_id_floor,
        ))
    }

    /// Running serving statistics (shared with the `stats` verb).
    pub fn stats(&self) -> Arc<Mutex<ReportAccumulator>> {
        self.stats.clone()
    }

    /// Run until the request channel closes and all work drains.
    /// Returns the number of completed (non-cancelled) generations.
    pub fn serve(mut self, rx: Receiver<RtMsg>) -> Result<u64> {
        self.core.start(EngineClock::wall())?;
        let t0 = Instant::now();
        // Journal-recovered turns first: they were admitted (and
        // acked) before the crash, so they re-enter ahead of any new
        // arrival, cache-cold but with their ids and flows intact.
        for (s, flow) in std::mem::take(&mut self.recovered) {
            self.submit_recovered(s, flow)?;
        }
        let mut open = true;
        loop {
            // Intake — block only when there is nothing else to do.
            if open {
                if !self.core.has_work() && self.parked.is_empty() {
                    match rx.recv() {
                        Ok(m) => self.handle_msg(m)?,
                        Err(_) => open = false,
                    }
                }
                loop {
                    match rx.try_recv() {
                        Ok(m) => self.handle_msg(m)?,
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
                // Group commit: one fsync covers the whole intake
                // batch, then the held-back accepted frames go out —
                // an acked turn is always durable.
                self.flush_acks()?;
            }
            if !self.core.has_work() {
                if !self.parked.is_empty() {
                    // an idle engine is by definition not overloaded
                    self.resume_one()?;
                    continue;
                }
                if !open {
                    return Ok(self.served);
                }
                continue;
            }
            self.step_once(&t0)?;
        }
    }

    fn journal_append(&mut self, rec: Record) -> Result<()> {
        if let Some(j) = self.journal.as_mut() {
            j.append(&rec)?;
        }
        Ok(())
    }

    fn flush_acks(&mut self) -> Result<()> {
        if self.pending_acks.is_empty() {
            return Ok(());
        }
        if let Some(j) = self.journal.as_mut() {
            j.sync()?;
        }
        for id in std::mem::take(&mut self.pending_acks) {
            if let Some(sub) = self.subs.get(&id) {
                let _ = sub.tx.send(TokenEvent::Accepted { id });
            }
        }
        Ok(())
    }

    fn handle_msg(&mut self, m: RtMsg) -> Result<()> {
        match m {
            RtMsg::Submit(r) => {
                match self.gate.try_admit(r.priority, r.session.as_deref()) {
                    AdmissionDecision::Admit => {}
                    AdmissionDecision::Displace(victim) => {
                        self.gate.forget_waiting(victim);
                        self.shed_victim(victim)?;
                        relock(&self.stats).displaced += 1;
                    }
                    AdmissionDecision::Reject => {
                        relock(&self.stats).rejected += 1;
                        let _ = r.events.send(TokenEvent::Rejected {
                            id: r.id,
                            retry_after_ms: self.gate.cfg().retry_after_ms,
                        });
                        return Ok(());
                    }
                }
                self.admit(r)?;
            }
            RtMsg::Cancel(id) => {
                if let Some(p) = self.parked.remove(&id) {
                    // parked turns are live (journaled, resumable)
                    // until explicitly cancelled
                    self.journal_append(Record::Cancelled { id })?;
                    if let Some(tx) = p.tx {
                        let _ = tx.send(TokenEvent::Cancelled { id });
                    }
                    relock(&self.stats).cancelled += 1;
                } else if self.core.cancel(id)? {
                    // the engine streams the terminal Cancelled on the
                    // next step; unknown ids are a harmless no-op
                    self.journal_append(Record::Cancelled { id })?;
                }
            }
        }
        Ok(())
    }

    /// Admit one submission: journal it (+ its session binding),
    /// register it with the gate, hold its accepted frame for the
    /// group commit, and hand it to the engine.
    fn admit(&mut self, r: RtRequest) -> Result<()> {
        // A session call is a node of an open-ended flow: the engine's
        // pool seeds its KV from the tag's previous call and retains it
        // again afterwards.  delta_start=0 marks the prompt
        // self-contained (no trace stitching).  `deps` turns calls into
        // DAG nodes: the engine holds this one until every referenced
        // generation finished.
        let flow = r.session.as_ref().map(|tag| {
            let (flow_id, turn_idx) = self.registry.resolve(tag, r.id);
            let mut deps = self.registry.resolve_deps(tag, &r.deps);
            if !r.deps.is_empty() && deps.is_empty() {
                // Every referenced generation is unknown or forgotten:
                // run now ("waits on fewer predecessors"), instead of
                // an empty list silently re-implying the linear chain.
                // A self-index is the explicit no-predecessors form
                // (`FlowBinding::dep_indices`).
                deps = vec![turn_idx];
            }
            FlowBinding {
                flow_id,
                turn_idx,
                total_turns: usize::MAX,
                think_time_us: 0.0,
                delta_start: 0,
                deps,
                node: NodeKind::Llm,
                crit_path: 1, // open-ended: depth unknown
            }
        });
        if self.journal.is_some() {
            self.journal_append(Record::Submit(SubmitRec {
                id: r.id,
                priority: r.priority,
                prompt: r.prompt.clone(),
                max_new_tokens: r.max_new_tokens,
                session: r.session.clone(),
                deps: r.deps.clone(),
            }))?;
            if let Some(b) = r.session.as_ref().and_then(|t| self.registry.snapshot(t)) {
                self.journal_append(Record::Bind(b))?;
            }
        }
        self.gate.admit(r.id, r.priority, r.session.as_deref());
        if r.priority == Priority::Proactive {
            self.ctx.insert(
                r.id,
                ProactiveCtx {
                    prompt: r.prompt.clone(),
                    max_new_tokens: r.max_new_tokens,
                    session: r.session.clone(),
                    flow: flow.clone(),
                },
            );
        }
        self.subs.insert(r.id, Sub { tx: r.events, skip: 0, emitted: 0 });
        self.pending_acks.push(r.id);
        self.core.submit(Request {
            id: r.id,
            priority: r.priority,
            arrival_us: 0.0, // re-stamped to wall now on submit
            prompt: r.prompt,
            max_new_tokens: r.max_new_tokens,
            profile: "uds".into(),
            flow,
        })?;
        Ok(())
    }

    /// Resubmit one journal-recovered turn.  No subscriber exists (the
    /// pre-crash connection died with the process) and the journal
    /// already holds its records, so this neither frames nor appends.
    fn submit_recovered(&mut self, s: SubmitRec, flow: Option<FlowBinding>) -> Result<()> {
        self.gate.admit(s.id, s.priority, s.session.as_deref());
        if s.priority == Priority::Proactive {
            self.ctx.insert(
                s.id,
                ProactiveCtx {
                    prompt: s.prompt.clone(),
                    max_new_tokens: s.max_new_tokens,
                    session: s.session.clone(),
                    flow: flow.clone(),
                },
            );
        }
        self.core.submit(Request {
            id: s.id,
            priority: s.priority,
            arrival_us: 0.0,
            prompt: s.prompt,
            max_new_tokens: s.max_new_tokens,
            profile: "uds".into(),
            flow,
        })?;
        Ok(())
    }

    /// Shed one queued proactive victim: journal the shed, cancel it
    /// in the engine; its `Cancelled` event becomes a terminal
    /// [`TokenEvent::Shed`] frame.
    fn shed_victim(&mut self, id: ReqId) -> Result<()> {
        self.ctx.remove(&id);
        self.journal_append(Record::Shed { id })?;
        self.shedding.insert(id);
        relock(&self.stats).shed += 1;
        if !self.core.cancel(id)? {
            // beat us to a terminal: nothing to shed after all
            self.shedding.remove(&id);
        }
        Ok(())
    }

    /// Preempt-and-park one running proactive decode.  The turn stays
    /// logically live (its journal records stand); once pressure
    /// clears it resumes under the *same* generation id, re-prefilling
    /// cache-cold, with already-streamed tokens suppressed so the
    /// client stream never duplicates.  Flow turns are shed instead of
    /// parked (their node bookkeeping cannot be replayed mid-flow).
    fn park(&mut self, id: ReqId) -> Result<()> {
        match self.ctx.remove(&id) {
            Some(ctx) if ctx.flow.is_none() => {
                let sub = self.subs.remove(&id);
                let emitted = sub.as_ref().map(|s| s.emitted).unwrap_or(0);
                self.parked
                    .insert(id, ParkedReq { tx: sub.map(|s| s.tx), ctx, emitted });
                relock(&self.stats).parked += 1;
                let _ = self.core.cancel(id)?;
            }
            Some(_) | None => {
                self.shedding.insert(id);
                self.journal_append(Record::Shed { id })?;
                relock(&self.stats).shed += 1;
                if !self.core.cancel(id)? {
                    self.shedding.remove(&id);
                }
            }
        }
        Ok(())
    }

    /// Resume the oldest parked generation (overload has cleared).
    fn resume_one(&mut self) -> Result<()> {
        let Some((id, p)) = self.parked.pop_first() else {
            return Ok(());
        };
        self.gate.admit(id, Priority::Proactive, p.ctx.session.as_deref());
        if let Some(tx) = p.tx {
            self.subs.insert(id, Sub { tx, skip: p.emitted, emitted: p.emitted });
        }
        self.ctx.insert(id, p.ctx.clone());
        relock(&self.stats).resumed += 1;
        self.core.submit(Request {
            id,
            priority: Priority::Proactive,
            arrival_us: 0.0,
            prompt: p.ctx.prompt,
            max_new_tokens: p.ctx.max_new_tokens,
            profile: "uds".into(),
            flow: p.ctx.flow,
        })?;
        Ok(())
    }

    /// Room to resume a parked decode: below half the queue bound.
    fn room_to_resume(&self) -> bool {
        let cap = self.gate.cfg().max_queue_depth;
        cap == 0 || self.gate.live() < (cap + 1) / 2
    }

    /// One decision point of the shared coordinator policy, followed
    /// by one detector pass (pause / shed one / park one — gradual by
    /// construction).
    fn step_once(&mut self, t0: &Instant) -> Result<()> {
        for ev in self.core.step()? {
            self.gate.on_event(&ev);
            // Cancelled events are counted where their frame is sent:
            // a shed or park is not a client cancel.
            if !matches!(ev, EngineEvent::Cancelled { .. }) {
                relock(&self.stats).absorb(&ev);
            }
            match ev {
                EngineEvent::TokenEmitted { id, token, n, .. } => {
                    if let Some(sub) = self.subs.get_mut(&id) {
                        if sub.skip > 0 {
                            // replayed after a park/resume: the client
                            // already has this position
                            sub.skip -= 1;
                        } else {
                            sub.emitted += 1;
                            let _ = sub.tx.send(TokenEvent::Token { id, token, n });
                        }
                    }
                }
                EngineEvent::TurnDone {
                    id,
                    at_us,
                    arrival_us,
                    first_token_us,
                    tokens,
                    cached_prefix,
                } => {
                    self.served += 1;
                    self.ctx.remove(&id);
                    self.journal_append(Record::Done { id })?;
                    if let Some(sub) = self.subs.remove(&id) {
                        let _ = sub.tx.send(TokenEvent::Done {
                            id,
                            ttft_ms: (first_token_us - arrival_us) / 1e3,
                            total_ms: (at_us - arrival_us) / 1e3,
                            tokens,
                            cached_prefix,
                        });
                    }
                }
                EngineEvent::Cancelled { id, .. } => {
                    if self.shedding.remove(&id) {
                        self.ctx.remove(&id);
                        if let Some(sub) = self.subs.remove(&id) {
                            let _ = sub.tx.send(TokenEvent::Shed {
                                id,
                                retry_after_ms: self.gate.cfg().retry_after_ms,
                            });
                        }
                    } else if self.parked.contains_key(&id) {
                        // the preemption half of a park: not terminal
                    } else {
                        relock(&self.stats).cancelled += 1;
                        self.ctx.remove(&id);
                        if let Some(sub) = self.subs.remove(&id) {
                            let _ = sub.tx.send(TokenEvent::Cancelled { id });
                        }
                    }
                }
                EngineEvent::Admitted { .. }
                | EngineEvent::Preempted { .. }
                | EngineEvent::Rebound { .. }
                | EngineEvent::KvEvicted { .. }
                | EngineEvent::SessionEvicted { .. } => {}
            }
        }
        let now_us = t0.elapsed().as_secs_f64() * 1e6;
        let sig = self.gate.signal(now_us);
        let level = self.core.overload_response(&sig);
        self.gate.set_paused(level >= ShedLevel::PauseProactive);
        if level >= ShedLevel::CancelQueuedProactive {
            if let Some(v) = self.gate.take_newest_waiting_proactive() {
                self.shed_victim(v)?;
            }
        }
        if level >= ShedLevel::ParkRunningProactive {
            if let Some(v) = self.gate.take_newest_running_proactive() {
                self.park(v)?;
            }
        }
        if level == ShedLevel::None && !self.parked.is_empty() && self.room_to_resume() {
            self.resume_one()?;
        }
        Ok(())
    }
}

/// Convenience used by tests and the UDS layer: run a serving loop on
/// its own thread, returning the (bounded) message sender and the live
/// stats.
pub fn spawn(
    bridge: Arc<ExecBridge>,
    soc: SocConfig,
    sched: SchedulerConfig,
) -> (SyncSender<RtMsg>, Arc<Mutex<ReportAccumulator>>) {
    spawn_with_policy(bridge, soc, sched, "agent-xpu")
        .expect("the default policy is always registered")
}

/// Like [`spawn`], serving any registered policy by name.
pub fn spawn_with_policy(
    bridge: Arc<ExecBridge>,
    soc: SocConfig,
    sched: SchedulerConfig,
    policy: &str,
) -> Result<(SyncSender<RtMsg>, Arc<Mutex<ReportAccumulator>>)> {
    spawn_full(bridge, soc, sched, policy, OverloadConfig::default(), None)
        .map(|(tx, stats, _)| (tx, stats))
}

/// Like [`spawn_with_policy`] plus overload knobs and an optional
/// journal.  Also returns the generation-id floor recovered from the
/// journal (1 when none): callers must mint ids at or above it.
pub fn spawn_full(
    bridge: Arc<ExecBridge>,
    soc: SocConfig,
    sched: SchedulerConfig,
    policy: &str,
    overload: OverloadConfig,
    journal: Option<PathBuf>,
) -> Result<(SyncSender<RtMsg>, Arc<Mutex<ReportAccumulator>>, u64)> {
    let bound = if overload.max_queue_depth > 0 {
        overload.max_queue_depth
    } else {
        INTAKE_FALLBACK_BOUND
    };
    let (tx, rx) = sync_channel(bound);
    let (sched, floor) = RtScheduler::new_full(bridge, soc, sched, policy, overload, journal)?;
    let stats = sched.stats();
    std::thread::spawn(move || {
        let _ = sched.serve(rx);
    });
    Ok((tx, stats, floor))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_soc, llama32_3b};
    use crate::server::journal::Journal;
    use std::sync::mpsc::channel;

    fn bridge() -> Arc<ExecBridge> {
        let mut geo = llama32_3b();
        geo.n_layers = 2;
        Arc::new(ExecBridge::synthetic(geo))
    }

    fn spawn_default() -> (SyncSender<RtMsg>, Arc<Mutex<ReportAccumulator>>) {
        spawn(bridge(), default_soc(), SchedulerConfig::default())
    }

    fn submit(
        tx: &SyncSender<RtMsg>,
        id: u64,
        priority: Priority,
        plen: usize,
        maxnew: usize,
    ) -> Receiver<TokenEvent> {
        let (etx, erx) = channel();
        tx.send(RtMsg::Submit(RtRequest {
            id,
            priority,
            prompt: vec![1; plen],
            max_new_tokens: maxnew,
            session: None,
            deps: vec![],
            events: etx,
        }))
        .unwrap();
        erx
    }

    fn submit_session(
        tx: &SyncSender<RtMsg>,
        id: u64,
        session: &str,
        prompt: Vec<i32>,
        maxnew: usize,
    ) -> Receiver<TokenEvent> {
        let (etx, erx) = channel();
        tx.send(RtMsg::Submit(RtRequest {
            id,
            priority: Priority::Reactive,
            prompt,
            max_new_tokens: maxnew,
            session: Some(session.into()),
            deps: vec![],
            events: etx,
        }))
        .unwrap();
        erx
    }

    fn done_of(events: &[TokenEvent]) -> (Vec<i32>, usize) {
        match events.last().unwrap() {
            TokenEvent::Done { tokens, cached_prefix, .. } => {
                (tokens.clone(), *cached_prefix)
            }
            e => panic!("expected Done, got {e:?}"),
        }
    }

    #[test]
    fn serves_a_request_with_streaming() {
        let (tx, _) = spawn_default();
        let erx = submit(&tx, 1, Priority::Reactive, 100, 5);
        drop(tx);
        let events: Vec<TokenEvent> = erx.iter().collect();
        assert!(matches!(events[0], TokenEvent::Accepted { id: 1 }));
        let toks: Vec<&TokenEvent> = events
            .iter()
            .filter(|e| matches!(e, TokenEvent::Token { .. }))
            .collect();
        assert_eq!(toks.len(), 5);
        match events.last().unwrap() {
            TokenEvent::Done { id, tokens, ttft_ms, total_ms, .. } => {
                assert_eq!(*id, 1);
                assert_eq!(tokens.len(), 5);
                assert!(*ttft_ms >= 0.0 && *total_ms >= *ttft_ms);
            }
            e => panic!("expected Done, got {e:?}"),
        }
    }

    #[test]
    fn session_calls_reuse_the_conversation_prefix() {
        // call 1 establishes the session; call 2 extends the exact
        // conversation (prompt + generated tokens) with new user input
        let (tx, stats) = spawn_default();
        let prompt1: Vec<i32> = vec![5; 40];
        let erx1 = submit_session(&tx, 1, "chat-1", prompt1.clone(), 4);
        let ev1: Vec<TokenEvent> = erx1.iter().collect();
        let (toks1, cached1) = done_of(&ev1);
        assert_eq!(cached1, 0, "first call has nothing to reuse");
        assert_eq!(toks1.len(), 4);

        let mut prompt2 = prompt1;
        prompt2.extend(&toks1);
        prompt2.extend(vec![6; 16]);
        let erx2 = submit_session(&tx, 2, "chat-1", prompt2.clone(), 3);
        let ev2: Vec<TokenEvent> = erx2.iter().collect();
        let (toks2, cached2) = done_of(&ev2);
        assert_eq!(toks2.len(), 3);
        // KV covers prompt1 + 3 of the 4 generated tokens
        assert_eq!(cached2, 43, "second call must reuse the session KV");

        // an unrelated session starts cold
        let erx3 = submit_session(&tx, 3, "chat-2", prompt2, 2);
        drop(tx);
        let (_, cached3) = done_of(&erx3.iter().collect::<Vec<_>>());
        assert_eq!(cached3, 0);
        // stats accumulated incrementally from the event stream
        let s = relock(&stats);
        assert_eq!(s.served, 3);
        assert_eq!(s.tokens, 4 + 3 + 2);
        assert_eq!(s.reused_prefix_tokens, 43);
    }

    #[test]
    fn session_registry_is_bounded_and_ids_are_stable() {
        let mut reg = SessionRegistry::default();
        let (a, t0) = reg.resolve("a", 1);
        assert_eq!(t0, 0);
        let (a2, t1) = reg.resolve("a", 2);
        assert_eq!((a2, t1), (a, 1), "same tag, same id, next turn");
        let (b, _) = reg.resolve("b", 3);
        assert_ne!(a, b);
        // generation ids resolve to node indices for DAG deps
        assert_eq!(reg.resolve_deps("a", &[1, 2]), vec![0, 1]);
        assert_eq!(reg.resolve_deps("a", &[99]), Vec::<usize>::new(), "unknown ids drop");
        // overflow the registry: oldest tags are forgotten...
        for i in 0..SESSION_TAGS_MAX {
            reg.resolve(&format!("t{i}"), 100 + i as u64);
        }
        assert!(reg.get("a").is_none(), "oldest tag evicted");
        // ...and ids are monotonic, so a re-registered tag can never
        // alias another session's retained cache
        let (a3, t) = reg.resolve("a", 9999);
        assert!(a3 > b);
        assert_eq!(t, 0, "a forgotten tag starts cold");
    }

    #[test]
    fn session_registry_restores_journal_bindings() {
        let mut reg = SessionRegistry::default();
        reg.restore(&BindRec {
            tag: "chat".into(),
            flow_id: 7,
            calls: 2,
            turn_of: vec![(10, 0), (11, 1), (12, 2)],
        });
        assert_eq!(reg.lookup("chat", 11), Some((7, 1)));
        // the mint counter restarted above the restored flow
        let (next_id, t) = reg.resolve("fresh", 13);
        assert!(next_id > 7);
        assert_eq!(t, 0);
        // the restored tag continues its call count, not restarts it
        let (fid, t) = reg.resolve("chat", 14);
        assert_eq!((fid, t), (7, 3));
    }

    #[test]
    fn dag_deps_between_session_calls_complete_without_deadlock() {
        let (tx, stats) = spawn_default();
        let (etx0, erx0) = channel();
        tx.send(RtMsg::Submit(RtRequest {
            id: 1,
            priority: Priority::Reactive,
            prompt: vec![5; 120],
            max_new_tokens: 12,
            session: Some("wf".into()),
            deps: vec![],
            events: etx0,
        }))
        .unwrap();
        // two fan-out calls over the root + a join over both, submitted
        // immediately (the engine holds them until their deps finish)
        let submit_dep = |id: u64, deps: Vec<u64>| {
            let (etx, erx) = channel();
            tx.send(RtMsg::Submit(RtRequest {
                id,
                priority: Priority::Reactive,
                prompt: vec![6; 40],
                max_new_tokens: 4,
                session: Some("wf".into()),
                deps,
                events: etx,
            }))
            .unwrap();
            erx
        };
        let erx2 = submit_dep(2, vec![1]);
        let erx3 = submit_dep(3, vec![1]);
        let erx4 = submit_dep(4, vec![2, 3]);
        drop(tx);
        for erx in [erx0, erx2, erx3, erx4] {
            let events: Vec<TokenEvent> = erx.iter().collect();
            assert!(
                matches!(events.last().unwrap(), TokenEvent::Done { .. }),
                "DAG call must finish, got {:?}",
                events.last()
            );
        }
        assert_eq!(relock(&stats).served, 4);
    }

    #[test]
    fn diverged_session_prompt_recomputes() {
        let (tx, _) = spawn_default();
        let erx1 = submit_session(&tx, 1, "s", vec![5; 30], 3);
        let _ = erx1.iter().collect::<Vec<_>>();
        // same session, unrelated prompt → no usable prefix
        let erx2 = submit_session(&tx, 2, "s", vec![9; 30], 3);
        drop(tx);
        let (_, cached) = done_of(&erx2.iter().collect::<Vec<_>>());
        assert_eq!(cached, 0);
    }

    #[test]
    fn serves_concurrent_mixed_requests() {
        let (tx, _) = spawn_default();
        let rx1 = submit(&tx, 1, Priority::Proactive, 200, 8);
        let rx2 = submit(&tx, 2, Priority::Reactive, 64, 4);
        let rx3 = submit(&tx, 3, Priority::Proactive, 64, 4);
        drop(tx);
        for rx in [rx1, rx2, rx3] {
            let events: Vec<TokenEvent> = rx.iter().collect();
            assert!(
                matches!(events.last().unwrap(), TokenEvent::Done { .. }),
                "{events:?}"
            );
        }
    }

    #[test]
    fn cancel_aborts_an_inflight_generation() {
        let (tx, stats) = spawn_default();
        // a generation long enough that the cancel always lands first
        let erx = submit(&tx, 1, Priority::Reactive, 64, 200_000);
        tx.send(RtMsg::Cancel(1)).unwrap();
        drop(tx);
        let events: Vec<TokenEvent> = erx.iter().collect();
        assert!(matches!(events[0], TokenEvent::Accepted { id: 1 }));
        assert!(
            matches!(events.last().unwrap(), TokenEvent::Cancelled { id: 1 }),
            "terminal frame must be Cancelled, got {:?}",
            events.last()
        );
        assert_eq!(relock(&stats).cancelled, 1);
    }

    #[test]
    fn cancel_of_unknown_id_is_harmless() {
        let (tx, _) = spawn_default();
        tx.send(RtMsg::Cancel(999)).unwrap();
        let erx = submit(&tx, 1, Priority::Reactive, 64, 3);
        drop(tx);
        let events: Vec<TokenEvent> = erx.iter().collect();
        assert!(matches!(events.last().unwrap(), TokenEvent::Done { .. }));
    }

    #[test]
    fn any_registered_policy_serves_the_same_protocol() {
        // the serve --policy path: a baseline and the EDF policy drive
        // the identical wire loop
        for policy in ["deadline", "cpu-fcfs"] {
            let (tx, stats) = spawn_with_policy(
                bridge(),
                default_soc(),
                SchedulerConfig::default(),
                policy,
            )
            .unwrap();
            let erx = submit(&tx, 1, Priority::Reactive, 80, 3);
            drop(tx);
            let events: Vec<TokenEvent> = erx.iter().collect();
            assert!(
                matches!(events.last().unwrap(), TokenEvent::Done { .. }),
                "{policy}: {events:?}"
            );
            assert_eq!(relock(&stats).served, 1, "{policy}");
        }
        assert!(
            spawn_with_policy(
                bridge(),
                default_soc(),
                SchedulerConfig::default(),
                "no-such-policy",
            )
            .is_err(),
            "unknown policy names fail fast"
        );
    }

    #[test]
    fn session_capacity_zero_disables_serving_reuse() {
        // the config knob the simulated coordinator honors now reaches
        // the server too
        let mut sched = SchedulerConfig::default();
        sched.session_capacity = 0;
        let (tx, _) = spawn(bridge(), default_soc(), sched);
        let p: Vec<i32> = vec![5; 30];
        let erx1 = submit_session(&tx, 1, "s", p.clone(), 3);
        let (toks1, _) = done_of(&erx1.iter().collect::<Vec<_>>());
        let mut p2 = p;
        p2.extend(&toks1);
        p2.extend(vec![6; 8]);
        let erx2 = submit_session(&tx, 2, "s", p2, 2);
        drop(tx);
        let (_, cached) = done_of(&erx2.iter().collect::<Vec<_>>());
        assert_eq!(cached, 0, "capacity 0 must disable retention");
    }

    #[test]
    fn full_queue_rejects_with_retry_after() {
        let overload = OverloadConfig { max_queue_depth: 1, ..OverloadConfig::default() };
        let (tx, stats, floor) = spawn_full(
            bridge(),
            default_soc(),
            SchedulerConfig::default(),
            "agent-xpu",
            overload,
            None,
        )
        .unwrap();
        assert_eq!(floor, 1, "no journal: ids start at 1");
        // fill the single slot with a REACTIVE generation that cannot
        // finish before the second submission is processed — reactive
        // work is never shed, so the queue stays provably full
        let erx1 = submit(&tx, 1, Priority::Reactive, 64, 200_000);
        assert!(matches!(
            erx1.recv().unwrap(),
            TokenEvent::Accepted { id: 1 }
        ));
        // depth is now 1 = max: the next proactive arrival is refused
        let erx2 = submit(&tx, 2, Priority::Proactive, 64, 4);
        match erx2.recv().unwrap() {
            TokenEvent::Rejected { id: 2, retry_after_ms } => {
                assert!(retry_after_ms > 0.0, "retry hint must be positive");
            }
            e => panic!("expected Rejected, got {e:?}"),
        }
        tx.send(RtMsg::Cancel(1)).unwrap();
        drop(tx);
        let ev1: Vec<TokenEvent> = erx1.iter().collect();
        assert!(matches!(ev1.last().unwrap(), TokenEvent::Cancelled { id: 1 }));
        assert_eq!(relock(&stats).rejected, 1);
    }

    #[test]
    fn journal_recovery_resumes_pending_turns() {
        let dir = std::env::temp_dir().join(format!(
            "agent-xpu-rt-recovery-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.journal");
        let _ = std::fs::remove_file(&path);
        // a "crashed" server: one admitted session turn, never finished
        {
            let (mut j, _) = Journal::open(&path, 1).unwrap();
            j.append(&Record::Submit(SubmitRec {
                id: 7,
                priority: Priority::Reactive,
                prompt: vec![5; 40],
                max_new_tokens: 3,
                session: Some("chat".into()),
                deps: vec![],
            }))
            .unwrap();
            j.append(&Record::Bind(BindRec {
                tag: "chat".into(),
                flow_id: 2,
                calls: 0,
                turn_of: vec![(7, 0)],
            }))
            .unwrap();
            j.sync().unwrap();
        }
        let (tx, stats, floor) = spawn_full(
            bridge(),
            default_soc(),
            SchedulerConfig::default(),
            "agent-xpu",
            OverloadConfig::default(),
            Some(path.clone()),
        )
        .unwrap();
        assert_eq!(floor, 8, "ids restart above everything journaled");
        drop(tx);
        // the recovered turn replays to completion with no client
        let deadline = Instant::now() + std::time::Duration::from_secs(30);
        loop {
            {
                let s = relock(&stats);
                if s.recovered == 1 && s.served == 1 {
                    break;
                }
                assert!(
                    Instant::now() < deadline,
                    "recovered turn never finished: {s:?}"
                );
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn poisoned_stats_mutex_does_not_take_down_the_server() {
        // regression: a panicking reader used to poison the lock and
        // wedge every subsequent stats access
        let stats = Arc::new(Mutex::new(ReportAccumulator::new()));
        let s2 = stats.clone();
        let _ = std::thread::spawn(move || {
            let _g = s2.lock().unwrap(); // lint:allow(lock-hygiene) this test deliberately poisons the mutex
            panic!("poison the lock");
        })
        .join();
        assert!(stats.lock().is_err(), "the mutex must actually be poisoned");
        relock(&stats).served += 1;
        assert_eq!(relock(&stats).served, 1, "relock reads through poison");
    }
}
