//! Real-time scheduler: the online coordinator policy (dual queues,
//! reactive-first kernel-level preemption, decode batching) executed
//! against *wall-clock* time with real PJRT compute.
//!
//! Sessions: a request carrying a `session` tag retains its KV after
//! completion, keyed by that tag, and the session's next call prefills
//! only the tokens beyond the retained conversation prefix — the
//! serving-side face of flow-level cross-turn reuse (DESIGN.md §3).
//! Retention is LRU-bounded.
//!
//! The CPU PJRT substrate serializes kernel execution on one compute
//! thread, so "the pipelines" collapse to one lane — but the scheduling
//! decisions (who runs the next kernel, who joins the decode batch, who
//! gets preempted at a kernel boundary) are exactly the coordinator's,
//! which is what the serving frontend needs.

use std::collections::HashMap;
use std::sync::Arc;
use std::sync::mpsc::{Receiver, Sender, channel};
use std::time::Instant;

use anyhow::Result;

use crate::engine::{ExecBridge, Phase, ReqState};
use crate::runtime::SessionCachePool;
use crate::workload::{Priority, ReqId, Request};

/// Max sessions whose KV stays resident between calls (LRU beyond).
const SESSION_CAPACITY: usize = 32;

/// Max session *tags* remembered by the server.  Tags arrive from
/// clients, so the map must be bounded for a long-lived server; when
/// it overflows, the oldest tag (and its retained KV, if any) is
/// forgotten — that session's next call simply starts cold.
const SESSION_TAGS_MAX: usize = 1024;

/// Bounded session-tag registry: maps client tags to stable pool keys.
/// Ids are monotonic (never reused), so a forgotten tag can never
/// alias another session's retained cache.
#[derive(Default)]
struct SessionRegistry {
    ids: HashMap<String, u64>,
    order: std::collections::VecDeque<String>,
    next: u64,
}

impl SessionRegistry {
    /// Resolve a tag to its pool key, registering it if new; evicts the
    /// oldest tag (dropping its pool entry) beyond `SESSION_TAGS_MAX`.
    fn resolve(&mut self, tag: &str, pool: &mut SessionCachePool) -> u64 {
        if let Some(&sid) = self.ids.get(tag) {
            return sid;
        }
        let sid = self.next;
        self.next += 1;
        self.ids.insert(tag.to_string(), sid);
        self.order.push_back(tag.to_string());
        while self.order.len() > SESSION_TAGS_MAX {
            if let Some(old) = self.order.pop_front() {
                if let Some(old_sid) = self.ids.remove(&old) {
                    pool.drop_session(old_sid);
                }
            }
        }
        sid
    }

    fn get(&self, tag: &str) -> Option<u64> {
        self.ids.get(tag).copied()
    }
}

/// A request submitted to the real-time scheduler.
pub struct RtRequest {
    pub id: ReqId,
    pub priority: Priority,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Session tag: calls sharing a tag reuse the retained KV of the
    /// previous call's conversation (`None` = single-shot).
    pub session: Option<String>,
    /// Streamed token events land here.
    pub events: Sender<TokenEvent>,
}

/// Streamed output.
#[derive(Debug, Clone, PartialEq)]
pub enum TokenEvent {
    Accepted { id: ReqId },
    Token { id: ReqId, token: i32, n: usize },
    Done {
        id: ReqId,
        ttft_ms: f64,
        total_ms: f64,
        tokens: Vec<i32>,
        /// Prompt tokens served from the session cache (0 = no reuse).
        cached_prefix: usize,
    },
    Error { id: ReqId, message: String },
}

struct Active {
    st: ReqState,
    events: Sender<TokenEvent>,
    session: Option<String>,
    t_arrive: Instant,
    t_first: Option<Instant>,
    sent: usize,
}

/// The real-time coordinator loop.  Owns the bridge (and through it the
/// PJRT runtime); consumes `RtRequest`s from a channel until it closes.
pub struct RtScheduler {
    bridge: Arc<ExecBridge>,
    b_max: usize,
    max_chunk: usize,
}

impl RtScheduler {
    pub fn new(bridge: Arc<ExecBridge>, b_max: usize) -> Self {
        let max_chunk = bridge.geo.max_chunk();
        Self { bridge, b_max, max_chunk }
    }

    /// Run until the request channel closes and all work drains.
    pub fn serve(&self, rx: Receiver<RtRequest>) -> Result<u64> {
        let mut active: Vec<Active> = vec![];
        let mut served = 0u64;
        let mut open = true;
        // session-tag → pool key, plus the retained KV itself; both
        // live exactly as long as this serve loop
        let mut session_ids = SessionRegistry::default();
        let mut sessions = SessionCachePool::new(SESSION_CAPACITY);
        let t0 = Instant::now();
        loop {
            let now_us = t0.elapsed().as_secs_f64() * 1e6;
            // Admit — block only when there is nothing to do.
            if open {
                if active.is_empty() {
                    match rx.recv() {
                        Ok(r) => {
                            self.admit(&mut active, r, &mut sessions, &mut session_ids)
                        }
                        Err(_) => open = false,
                    }
                }
                loop {
                    match rx.try_recv() {
                        Ok(r) => {
                            self.admit(&mut active, r, &mut sessions, &mut session_ids)
                        }
                        Err(std::sync::mpsc::TryRecvError::Empty) => break,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
            if active.is_empty() {
                if !open {
                    return Ok(served);
                }
                continue;
            }

            // One scheduling decision = one kernel, reactive first
            // (kernel-level preemption: proactive work pauses at this
            // boundary whenever a reactive request is present).
            self.run_one_kernel(&mut active)?;

            // Retire finished requests.
            let mut i = 0;
            while i < active.len() {
                if active[i].st.phase == Phase::Done {
                    let mut a = active.swap_remove(i);
                    let ttft = a
                        .t_first
                        .map(|t| t.duration_since(a.t_arrive).as_secs_f64() * 1e3)
                        .unwrap_or(f64::NAN);
                    let total = a.t_arrive.elapsed().as_secs_f64() * 1e3;
                    // park the conversation KV for the session's next call
                    if let Some(tag) = &a.session {
                        if let Some(sid) = session_ids.get(tag) {
                            let mut convo = a.st.req.prompt.clone();
                            convo.extend(&a.st.tokens);
                            sessions.retain(
                                sid,
                                a.st.cache.take(),
                                convo,
                                a.st.pos,
                                now_us,
                            );
                        }
                    }
                    let _ = a.events.send(TokenEvent::Done {
                        id: a.st.id(),
                        ttft_ms: ttft,
                        total_ms: total,
                        tokens: a.st.tokens.clone(),
                        cached_prefix: a.st.cached_prefix_len,
                    });
                    served += 1;
                } else {
                    i += 1;
                }
            }
        }
    }

    fn admit(
        &self,
        active: &mut Vec<Active>,
        r: RtRequest,
        sessions: &mut SessionCachePool,
        session_ids: &mut SessionRegistry,
    ) {
        let req = Request {
            id: r.id,
            priority: r.priority,
            arrival_us: 0.0,
            prompt: r.prompt,
            max_new_tokens: r.max_new_tokens,
            profile: "uds".into(),
            flow: None,
        };
        let _ = r.events.send(TokenEvent::Accepted { id: req.id });
        // resolve the session tag and claim any retained prefix KV
        let seed = r.session.as_ref().and_then(|tag| {
            let sid = session_ids.resolve(tag, sessions);
            sessions.take_match(sid, &req.prompt)
        });
        let st = self.bridge.init_state_with_session(req, self.max_chunk, seed);
        active.push(Active {
            st,
            events: r.events,
            session: r.session,
            t_arrive: Instant::now(),
            t_first: None,
            sent: 0,
        });
    }

    /// Pick and execute exactly one kernel according to the coordinator
    /// policy: reactive prefill > reactive decode (with proactive
    /// backfill) > proactive prefill > proactive decode batch.
    fn run_one_kernel(&self, active: &mut Vec<Active>) -> Result<()> {
        let pick_prefill = |active: &Vec<Active>, reactive: bool| -> Option<usize> {
            let mut idxs: Vec<usize> = (0..active.len())
                .filter(|&i| {
                    active[i].st.phase == Phase::Prefilling
                        && active[i].st.is_reactive() == reactive
                })
                .collect();
            idxs.sort_by_key(|&i| active[i].st.id());
            idxs.first().copied()
        };
        let decode_lanes = |active: &Vec<Active>, b_max: usize| -> Vec<usize> {
            let mut rt: Vec<usize> = (0..active.len())
                .filter(|&i| {
                    active[i].st.phase == Phase::Decoding && active[i].st.is_reactive()
                })
                .collect();
            let mut pro: Vec<usize> = (0..active.len())
                .filter(|&i| {
                    active[i].st.phase == Phase::Decoding && !active[i].st.is_reactive()
                })
                .collect();
            rt.append(&mut pro);
            rt.truncate(b_max);
            rt
        };

        if let Some(i) = pick_prefill(active, true) {
            self.prefill_step(&mut active[i])?;
            return Ok(());
        }
        let lanes = {
            let has_rt_decode = active
                .iter()
                .any(|a| a.st.phase == Phase::Decoding && a.st.is_reactive());
            if has_rt_decode { decode_lanes(active, self.b_max) } else { vec![] }
        };
        if !lanes.is_empty() {
            self.decode_step(active, &lanes)?;
            return Ok(());
        }
        if let Some(i) = pick_prefill(active, false) {
            self.prefill_step(&mut active[i])?;
            return Ok(());
        }
        let lanes = decode_lanes(active, self.b_max);
        if !lanes.is_empty() {
            self.decode_step(active, &lanes)?;
        }
        Ok(())
    }

    fn prefill_step(&self, a: &mut Active) -> Result<()> {
        let done = self.bridge.prefill_kernel_done(&mut a.st)?;
        if done {
            a.t_first = Some(Instant::now());
            self.flush_tokens(a);
        }
        Ok(())
    }

    fn decode_step(&self, active: &mut Vec<Active>, lanes: &[usize]) -> Result<()> {
        // take the lane states out to build &mut refs
        let mut sorted: Vec<usize> = lanes.to_vec();
        sorted.sort_unstable();
        // split_at_mut-free approach: temporarily move the states
        let mut taken: Vec<(usize, ReqState)> = vec![];
        for &i in sorted.iter().rev() {
            let st = std::mem::replace(
                &mut active[i].st,
                // placeholder; restored below
                self.bridge.init_state(
                    Request {
                        id: u64::MAX,
                        priority: Priority::Proactive,
                        arrival_us: 0.0,
                        prompt: vec![0],
                        max_new_tokens: 1,
                        profile: "placeholder".into(),
                        flow: None,
                    },
                    self.max_chunk,
                ),
            );
            taken.push((i, st));
        }
        {
            let mut refs: Vec<&mut ReqState> =
                taken.iter_mut().map(|(_, s)| s).collect();
            self.bridge.decode_iter_done(&mut refs)?;
        }
        for (i, st) in taken {
            active[i].st = st;
            self.flush_tokens(&mut active[i]);
        }
        Ok(())
    }

    fn flush_tokens(&self, a: &mut Active) {
        while a.sent < a.st.tokens.len() {
            let tok = a.st.tokens[a.sent];
            a.sent += 1;
            let _ = a.events.send(TokenEvent::Token {
                id: a.st.id(),
                token: tok,
                n: a.sent,
            });
        }
    }
}

/// Convenience used by tests and the UDS layer: run a scheduler on its
/// own thread, returning the request sender.
pub fn spawn(bridge: Arc<ExecBridge>, b_max: usize) -> Sender<RtRequest> {
    let (tx, rx) = channel();
    std::thread::spawn(move || {
        let sched = RtScheduler::new(bridge, b_max);
        let _ = sched.serve(rx);
    });
    tx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::llama32_3b;

    fn bridge() -> Arc<ExecBridge> {
        let mut geo = llama32_3b();
        geo.n_layers = 2;
        Arc::new(ExecBridge::synthetic(geo))
    }

    fn submit(
        tx: &Sender<RtRequest>,
        id: u64,
        priority: Priority,
        plen: usize,
        maxnew: usize,
    ) -> Receiver<TokenEvent> {
        let (etx, erx) = channel();
        tx.send(RtRequest {
            id,
            priority,
            prompt: vec![1; plen],
            max_new_tokens: maxnew,
            session: None,
            events: etx,
        })
        .unwrap();
        erx
    }

    fn submit_session(
        tx: &Sender<RtRequest>,
        id: u64,
        session: &str,
        prompt: Vec<i32>,
        maxnew: usize,
    ) -> Receiver<TokenEvent> {
        let (etx, erx) = channel();
        tx.send(RtRequest {
            id,
            priority: Priority::Reactive,
            prompt,
            max_new_tokens: maxnew,
            session: Some(session.into()),
            events: etx,
        })
        .unwrap();
        erx
    }

    fn done_of(events: &[TokenEvent]) -> (Vec<i32>, usize) {
        match events.last().unwrap() {
            TokenEvent::Done { tokens, cached_prefix, .. } => {
                (tokens.clone(), *cached_prefix)
            }
            e => panic!("expected Done, got {e:?}"),
        }
    }

    #[test]
    fn serves_a_request_with_streaming() {
        let tx = spawn(bridge(), 8);
        let erx = submit(&tx, 1, Priority::Reactive, 100, 5);
        drop(tx);
        let events: Vec<TokenEvent> = erx.iter().collect();
        assert!(matches!(events[0], TokenEvent::Accepted { id: 1 }));
        let toks: Vec<&TokenEvent> = events
            .iter()
            .filter(|e| matches!(e, TokenEvent::Token { .. }))
            .collect();
        assert_eq!(toks.len(), 5);
        match events.last().unwrap() {
            TokenEvent::Done { id, tokens, ttft_ms, .. } => {
                assert_eq!(*id, 1);
                assert_eq!(tokens.len(), 5);
                assert!(*ttft_ms >= 0.0);
            }
            e => panic!("expected Done, got {e:?}"),
        }
    }

    #[test]
    fn session_calls_reuse_the_conversation_prefix() {
        // call 1 establishes the session; call 2 extends the exact
        // conversation (prompt + generated tokens) with new user input
        let tx = spawn(bridge(), 8);
        let prompt1: Vec<i32> = vec![5; 40];
        let erx1 = submit_session(&tx, 1, "chat-1", prompt1.clone(), 4);
        let ev1: Vec<TokenEvent> = erx1.iter().collect();
        let (toks1, cached1) = done_of(&ev1);
        assert_eq!(cached1, 0, "first call has nothing to reuse");
        assert_eq!(toks1.len(), 4);

        let mut prompt2 = prompt1;
        prompt2.extend(&toks1);
        prompt2.extend(vec![6; 16]);
        let erx2 = submit_session(&tx, 2, "chat-1", prompt2.clone(), 3);
        let ev2: Vec<TokenEvent> = erx2.iter().collect();
        let (toks2, cached2) = done_of(&ev2);
        assert_eq!(toks2.len(), 3);
        // KV covers prompt1 + 3 of the 4 generated tokens
        assert_eq!(cached2, 43, "second call must reuse the session KV");

        // an unrelated session starts cold
        let erx3 = submit_session(&tx, 3, "chat-2", prompt2, 2);
        drop(tx);
        let (_, cached3) = done_of(&erx3.iter().collect::<Vec<_>>());
        assert_eq!(cached3, 0);
    }

    #[test]
    fn session_registry_is_bounded_and_ids_are_stable() {
        let mut reg = SessionRegistry::default();
        let mut pool = SessionCachePool::new(4);
        let a = reg.resolve("a", &mut pool);
        assert_eq!(reg.resolve("a", &mut pool), a, "same tag, same id");
        let b = reg.resolve("b", &mut pool);
        assert_ne!(a, b);
        // overflow the registry: oldest tags are forgotten...
        for i in 0..SESSION_TAGS_MAX {
            reg.resolve(&format!("t{i}"), &mut pool);
        }
        assert!(reg.get("a").is_none(), "oldest tag evicted");
        // ...and ids are monotonic, so a re-registered tag can never
        // alias another session's retained cache
        let a2 = reg.resolve("a", &mut pool);
        assert!(a2 > b);
    }

    #[test]
    fn diverged_session_prompt_recomputes() {
        let tx = spawn(bridge(), 8);
        let erx1 = submit_session(&tx, 1, "s", vec![5; 30], 3);
        let _ = erx1.iter().collect::<Vec<_>>();
        // same session, unrelated prompt → no usable prefix
        let erx2 = submit_session(&tx, 2, "s", vec![9; 30], 3);
        drop(tx);
        let (_, cached) = done_of(&erx2.iter().collect::<Vec<_>>());
        assert_eq!(cached, 0);
    }

    #[test]
    fn serves_concurrent_mixed_requests() {
        let tx = spawn(bridge(), 8);
        let rx1 = submit(&tx, 1, Priority::Proactive, 200, 8);
        let rx2 = submit(&tx, 2, Priority::Reactive, 64, 4);
        let rx3 = submit(&tx, 3, Priority::Proactive, 64, 4);
        drop(tx);
        for rx in [rx1, rx2, rx3] {
            let events: Vec<TokenEvent> = rx.iter().collect();
            assert!(
                matches!(events.last().unwrap(), TokenEvent::Done { .. }),
                "{events:?}"
            );
        }
    }
}
