//! Unix-Domain-Socket JSON-lines frontend (paper §7) over the
//! real-time serving loop, plus a small blocking client helper.
//!
//! A connection is full-duplex: `generate` streams its frames from a
//! writer thread while the reader keeps accepting lines, so a client
//! can `cancel` an in-flight generation (or pipeline several
//! generations) on the same connection.
//!
//! Error frames carry a machine-readable `code` alongside the human
//! `message`: `bad_request` (malformed JSON or invalid fields),
//! `unknown_id` (cancelling a generation this connection does not
//! own), `unknown_verb`.  Overload is not an error frame: a refused
//! admission is a terminal `retry_after` frame
//! (`{"type":"retry_after","id":…,"code":"overloaded",
//! "retry_after_ms":…}`), and a proactive generation shed mid-queue
//! ends with `{"type":"done.shed","id":…,"retry_after_ms":…}`.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError, channel};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result, bail};

use crate::config::{OverloadConfig, SchedulerConfig, SocConfig};
use crate::engine::ExecBridge;
use crate::metrics::ReportAccumulator;
use crate::util::json::Json;
use crate::workload::Priority;

use super::rt::{RtMsg, RtRequest, TokenEvent, relock, spawn_full};

/// The UDS server: accepts connections, parses request lines, streams
/// responses.
pub struct Server {
    socket_path: PathBuf,
    sched_tx: SyncSender<RtMsg>,
    next_id: Arc<AtomicU64>,
    stats: Arc<Mutex<ReportAccumulator>>,
    retry_after_ms: f64,
}

impl Server {
    /// Stand the serving loop up on the caller's SoC + scheduler
    /// configuration — the same knobs (`b_max`, `session_capacity`,
    /// preemption/backfill, …) the simulated coordinator honors.
    /// Serves the default `agent-xpu` policy.
    pub fn new(
        bridge: Arc<ExecBridge>,
        socket_path: impl AsRef<Path>,
        soc: SocConfig,
        sched: SchedulerConfig,
    ) -> Self {
        Self::with_policy(bridge, socket_path, soc, sched, "agent-xpu")
            .expect("the default policy is always registered")
    }

    /// Like [`Server::new`], serving any scheduling policy registered
    /// in `engine::registry` (`agent-xpu serve --policy <name>`).  The
    /// wire protocol is identical for every policy; unknown names fail
    /// here, before a socket is bound.
    pub fn with_policy(
        bridge: Arc<ExecBridge>,
        socket_path: impl AsRef<Path>,
        soc: SocConfig,
        sched: SchedulerConfig,
        policy: &str,
    ) -> Result<Self> {
        Self::with_options(
            bridge,
            socket_path,
            soc,
            sched,
            policy,
            OverloadConfig::default(),
            None,
        )
    }

    /// Full-control constructor: overload knobs (queue depth, live-flow
    /// budget, TTFT SLO, retry hint) and an optional write-ahead
    /// journal.  With a journal, a restarted server replays it before
    /// accepting connections — live turns resume and the generation-id
    /// counter restarts above everything ever issued.
    pub fn with_options(
        bridge: Arc<ExecBridge>,
        socket_path: impl AsRef<Path>,
        soc: SocConfig,
        sched: SchedulerConfig,
        policy: &str,
        overload: OverloadConfig,
        journal: Option<PathBuf>,
    ) -> Result<Self> {
        let retry_after_ms = overload.retry_after_ms;
        let (sched_tx, stats, id_floor) =
            spawn_full(bridge, soc, sched, policy, overload, journal)?;
        Ok(Self {
            socket_path: socket_path.as_ref().to_path_buf(),
            sched_tx,
            next_id: Arc::new(AtomicU64::new(id_floor.max(1))),
            stats,
            retry_after_ms,
        })
    }

    /// Bind and serve forever (one thread per connection).
    pub fn run(&self) -> Result<()> {
        let _ = std::fs::remove_file(&self.socket_path);
        let listener = UnixListener::bind(&self.socket_path)
            .with_context(|| format!("binding {:?}", self.socket_path))?;
        eprintln!("agent-xpu serving on {:?}", self.socket_path);
        for stream in listener.incoming() {
            let stream = stream?;
            let tx = self.sched_tx.clone();
            let next_id = self.next_id.clone();
            let stats = self.stats.clone();
            let retry_after_ms = self.retry_after_ms;
            std::thread::spawn(move || {
                if let Err(e) = handle_conn(stream, tx, next_id, stats, retry_after_ms) {
                    eprintln!("connection error: {e:#}");
                }
            });
        }
        Ok(())
    }
}

/// A structured error frame (`code` is machine-readable).
fn err_frame(code: &str, message: String) -> Json {
    Json::obj()
        .set("type", "error")
        .set("code", code)
        .set("message", message)
}

fn handle_conn(
    stream: UnixStream,
    tx: SyncSender<RtMsg>,
    next_id: Arc<AtomicU64>,
    stats: Arc<Mutex<ReportAccumulator>>,
    retry_after_ms: f64,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    // frames from concurrent generations interleave line-atomically
    let out = Arc::new(Mutex::new(stream));
    // ids issued on THIS connection — a client may only cancel its own
    // generations (ids are globally sequential, so without this check
    // any connection could abort any other's work)
    let mut my_ids: HashSet<u64> = HashSet::new();
    let say = |j: Json| -> Result<()> {
        writeln!(relock(&out), "{j}")?;
        Ok(())
    };
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                // malformed-request resilience (§6.5 error handling)
                say(err_frame("bad_request", format!("{e:#}")))?;
                continue;
            }
        };
        match msg.opt("type").and_then(|t| t.as_str().ok()) {
            Some("generate") => {
                let id = next_id.fetch_add(1, Ordering::SeqCst);
                match submit_generate(&tx, &msg, id) {
                    Ok(Some(erx)) => {
                        my_ids.insert(id);
                        // stream from a writer thread so this reader
                        // stays free for cancel / further generates
                        let out = out.clone();
                        std::thread::spawn(move || {
                            for ev in erx.iter() {
                                let terminal = matches!(
                                    ev,
                                    TokenEvent::Done { .. }
                                        | TokenEvent::Cancelled { .. }
                                        | TokenEvent::Rejected { .. }
                                        | TokenEvent::Shed { .. }
                                        | TokenEvent::Error { .. }
                                );
                                let mut o = relock(&out);
                                if writeln!(o, "{}", event_json(&ev)).is_err() {
                                    break;
                                }
                                if terminal {
                                    break;
                                }
                            }
                        });
                    }
                    Ok(None) => {
                        // the bounded intake channel itself is full:
                        // shed at the door, before the scheduler
                        relock(&stats).rejected += 1;
                        say(Json::obj()
                            .set("type", "retry_after")
                            .set("id", id as usize)
                            .set("code", "overloaded")
                            .set("retry_after_ms", retry_after_ms))?;
                    }
                    Err(e) => {
                        say(err_frame("bad_request", format!("{e:#}")))?;
                    }
                }
            }
            Some("cancel") => match msg.get("id").and_then(|v| v.as_usize()) {
                Ok(id) if my_ids.contains(&(id as u64)) => {
                    let _ = tx.send(RtMsg::Cancel(id as u64));
                    // the terminal done.cancelled frame arrives on the
                    // generation's own stream; ack the verb here
                    say(Json::obj().set("type", "cancel.ack").set("id", id))?;
                }
                Ok(id) => {
                    say(err_frame(
                        "unknown_id",
                        format!("no generation {id} on this connection"),
                    ))?;
                }
                Err(e) => {
                    say(err_frame("bad_request", format!("cancel needs an id: {e:#}")))?;
                }
            },
            Some("stats") => {
                let j = relock(&stats).to_json().set("type", "stats");
                say(j)?;
            }
            other => {
                say(err_frame("unknown_verb", format!("unknown type {other:?}")))?;
            }
        }
    }
}

/// Parse + validate one generate request and hand it to the scheduler.
/// `Ok(None)` means the bounded intake queue is full — the caller owes
/// the client a `retry_after` frame.
fn submit_generate(
    tx: &SyncSender<RtMsg>,
    msg: &Json,
    id: u64,
) -> Result<Option<Receiver<TokenEvent>>> {
    let prompt = msg.get("prompt")?.as_i32_vec()?;
    if prompt.is_empty() {
        bail!("empty prompt");
    }
    let priority = match msg.opt("priority").and_then(|p| p.as_str().ok()) {
        Some("proactive") => Priority::Proactive,
        _ => Priority::Reactive,
    };
    let max_new_tokens = msg
        .opt("max_new_tokens")
        .map(|v| v.as_usize())
        .unwrap_or(Ok(16))?;
    // Optional session tag: calls sharing it keep their KV alive
    // across the connection (flow-level reuse, DESIGN.md §3).
    let session = msg
        .opt("session")
        .and_then(|s| s.as_str().ok())
        .map(|s| s.to_string());
    // Optional DAG predecessors: generation ids of the same session
    // this call must wait for (fan-out/join workflows, DESIGN.md §3).
    let deps: Vec<u64> = match msg.opt("deps") {
        Some(v) => v.as_usize_vec()?.into_iter().map(|d| d as u64).collect(),
        None => vec![],
    };
    if !deps.is_empty() && session.is_none() {
        bail!("deps require a session tag");
    }
    let (etx, erx) = channel();
    match tx.try_send(RtMsg::Submit(RtRequest {
        id,
        priority,
        prompt,
        max_new_tokens,
        session,
        deps,
        events: etx,
    })) {
        Ok(()) => Ok(Some(erx)),
        Err(TrySendError::Full(_)) => Ok(None),
        Err(TrySendError::Disconnected(_)) => bail!("scheduler is down"),
    }
}

fn event_json(ev: &TokenEvent) -> Json {
    match ev {
        TokenEvent::Accepted { id } => Json::obj()
            .set("type", "accepted")
            .set("id", *id as usize),
        TokenEvent::Token { id, token, n } => Json::obj()
            .set("type", "token")
            .set("id", *id as usize)
            .set("token", *token)
            .set("n", *n),
        TokenEvent::Done { id, ttft_ms, total_ms, tokens, cached_prefix } => Json::obj()
            .set("type", "done")
            .set("id", *id as usize)
            .set("ttft_ms", *ttft_ms)
            .set("total_ms", *total_ms)
            .set("tokens", tokens.clone())
            .set("cached_prefix", *cached_prefix),
        TokenEvent::Cancelled { id } => Json::obj()
            .set("type", "done.cancelled")
            .set("id", *id as usize),
        TokenEvent::Rejected { id, retry_after_ms } => Json::obj()
            .set("type", "retry_after")
            .set("id", *id as usize)
            .set("code", "overloaded")
            .set("retry_after_ms", *retry_after_ms),
        TokenEvent::Shed { id, retry_after_ms } => Json::obj()
            .set("type", "done.shed")
            .set("id", *id as usize)
            .set("retry_after_ms", *retry_after_ms),
        TokenEvent::Error { id, message } => Json::obj()
            .set("type", "error")
            .set("id", *id as usize)
            .set("code", "internal")
            .set("message", message.as_str()),
    }
}

/// Result of one completed generate call.
#[derive(Debug, Clone)]
pub struct GenerateResult {
    pub tokens: Vec<i32>,
    pub ttft_ms: f64,
    pub total_ms: f64,
    /// Prompt tokens served from the session's retained KV.
    pub cached_prefix: usize,
}

/// Blocking client helper: send one generate request, return
/// (tokens, ttft_ms, total_ms).
pub fn client_generate(
    socket_path: impl AsRef<Path>,
    prompt: &[i32],
    priority: Priority,
    max_new_tokens: usize,
) -> Result<(Vec<i32>, f64, f64)> {
    let r = client_generate_session(socket_path, None, prompt, priority, max_new_tokens)?;
    Ok((r.tokens, r.ttft_ms, r.total_ms))
}

/// Like [`client_generate`], with an optional session tag: calls that
/// share a tag keep the conversation KV alive server-side, so a prompt
/// extending the previous call's conversation prefills only its delta.
///
/// Overload surfaces as errors naming the structured `code`: a
/// `retry_after` frame fails with `overloaded (retry after …ms)`, a
/// `done.shed` frame with `shed`, and an `error` frame carries its
/// server-assigned code.
pub fn client_generate_session(
    socket_path: impl AsRef<Path>,
    session: Option<&str>,
    prompt: &[i32],
    priority: Priority,
    max_new_tokens: usize,
) -> Result<GenerateResult> {
    let stream = UnixStream::connect(socket_path.as_ref())
        .with_context(|| format!("connecting {:?}", socket_path.as_ref()))?;
    let mut out = stream.try_clone()?;
    let mut req = Json::obj()
        .set("type", "generate")
        .set("priority", priority.label())
        .set("prompt", prompt.to_vec())
        .set("max_new_tokens", max_new_tokens);
    if let Some(s) = session {
        req = req.set("session", s);
    }
    writeln!(out, "{req}")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let msg = Json::parse(&line)?;
        match msg.get("type")?.as_str()? {
            "done" => {
                return Ok(GenerateResult {
                    tokens: msg.get("tokens")?.as_i32_vec()?,
                    ttft_ms: msg.get("ttft_ms")?.as_f64()?,
                    total_ms: msg.get("total_ms")?.as_f64()?,
                    cached_prefix: msg
                        .opt("cached_prefix")
                        .map(|v| v.as_usize())
                        .unwrap_or(Ok(0))?,
                });
            }
            "done.cancelled" => bail!("generation cancelled"),
            "done.shed" => bail!(
                "shed: generation dropped under overload (retry after {}ms)",
                msg.opt("retry_after_ms")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0)
            ),
            "retry_after" => bail!(
                "overloaded (retry after {}ms)",
                msg.opt("retry_after_ms")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0)
            ),
            "error" => {
                let code = msg
                    .opt("code")
                    .and_then(|c| c.as_str().ok().map(|s| s.to_string()))
                    .unwrap_or_else(|| "internal".to_string());
                bail!("server error [{code}]: {}", msg.get("message")?.as_str()?)
            }
            _ => {}
        }
    }
    bail!("connection closed before done")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_soc, llama32_3b};

    fn tmp_socket(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("agent-xpu-test-{name}-{}.sock", std::process::id()))
    }

    fn start_server(name: &str) -> PathBuf {
        let mut geo = llama32_3b();
        geo.n_layers = 2;
        let bridge = Arc::new(ExecBridge::synthetic(geo));
        let path = tmp_socket(name);
        let server =
            Server::new(bridge, &path, default_soc(), SchedulerConfig::default());
        let p = path.clone();
        std::thread::spawn(move || {
            let _ = server.run();
        });
        // wait for bind
        for _ in 0..200 {
            if p.exists() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        path
    }

    #[test]
    fn uds_roundtrip() {
        let path = start_server("roundtrip");
        let (tokens, ttft, total) =
            client_generate(&path, &[1, 2, 3, 4], Priority::Reactive, 5).unwrap();
        assert_eq!(tokens.len(), 5);
        assert!(ttft >= 0.0 && total >= ttft);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn uds_rejects_garbage_then_keeps_serving() {
        let path = start_server("garbage");
        let stream = UnixStream::connect(&path).unwrap();
        let mut out = stream.try_clone().unwrap();
        writeln!(out, "this is not json").unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let msg = Json::parse(&line).unwrap();
        assert_eq!(msg.get("type").unwrap().as_str().unwrap(), "error");
        assert_eq!(
            msg.get("code").unwrap().as_str().unwrap(),
            "bad_request",
            "error frames carry a structured code"
        );
        // the same connection still works
        writeln!(out, "{}", Json::obj().set("type", "stats")).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(
            Json::parse(&line).unwrap().get("type").unwrap().as_str().unwrap(),
            "stats"
        );
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn uds_error_codes_distinguish_failure_classes() {
        let path = start_server("codes");
        let stream = UnixStream::connect(&path).unwrap();
        let mut out = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        let mut code_of = |frame: Json| -> String {
            writeln!(out, "{frame}").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let msg = Json::parse(&line).unwrap();
            assert_eq!(msg.get("type").unwrap().as_str().unwrap(), "error");
            msg.get("code").unwrap().as_str().unwrap().to_string()
        };
        assert_eq!(
            code_of(Json::obj().set("type", "generate").set("prompt", Vec::<i32>::new())),
            "bad_request"
        );
        assert_eq!(
            code_of(Json::obj().set("type", "cancel").set("id", 123456usize)),
            "unknown_id"
        );
        assert_eq!(code_of(Json::obj().set("type", "frobnicate")), "unknown_verb");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn uds_session_field_keeps_kv_across_calls() {
        let path = start_server("session");
        let prompt: Vec<i32> = vec![4; 32];
        let first = client_generate_session(
            &path,
            Some("conv-1"),
            &prompt,
            Priority::Reactive,
            4,
        )
        .unwrap();
        assert_eq!(first.cached_prefix, 0);
        // extend the conversation with the actual reply + new input
        let mut next = prompt.clone();
        next.extend(&first.tokens);
        next.extend(vec![8; 12]);
        let second = client_generate_session(
            &path,
            Some("conv-1"),
            &next,
            Priority::Reactive,
            3,
        )
        .unwrap();
        // KV covers the 32-token prompt + 3 of the 4 reply tokens
        assert_eq!(second.cached_prefix, 35);
        // untagged calls never reuse
        let (toks, _, _) = client_generate(&path, &next, Priority::Reactive, 2).unwrap();
        assert_eq!(toks.len(), 2);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn uds_deps_field_submits_dag_calls() {
        let path = start_server("deps");
        let stream = UnixStream::connect(&path).unwrap();
        let mut out = stream.try_clone().unwrap();
        // root generation on session "wf"
        writeln!(
            out,
            "{}",
            Json::obj()
                .set("type", "generate")
                .set("prompt", vec![1i32; 64])
                .set("max_new_tokens", 6usize)
                .set("session", "wf")
        )
        .unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let acc = Json::parse(&line).unwrap();
        assert_eq!(acc.get("type").unwrap().as_str().unwrap(), "accepted");
        let root_id = acc.get("id").unwrap().as_usize().unwrap();
        // two parallel dependents held behind the root
        for _ in 0..2 {
            writeln!(
                out,
                "{}",
                Json::obj()
                    .set("type", "generate")
                    .set("prompt", vec![2i32; 32])
                    .set("max_new_tokens", 3usize)
                    .set("session", "wf")
                    .set("deps", vec![root_id])
            )
            .unwrap();
        }
        // read interleaved frames until all three generations are done
        let mut done = 0;
        while done < 3 {
            line.clear();
            reader.read_line(&mut line).unwrap();
            let msg = Json::parse(&line).unwrap();
            match msg.get("type").unwrap().as_str().unwrap() {
                "done" => done += 1,
                "error" => panic!("unexpected error frame: {line}"),
                _ => {}
            }
        }
        // deps without a session tag are rejected
        writeln!(
            out,
            "{}",
            Json::obj()
                .set("type", "generate")
                .set("prompt", vec![3i32; 8])
                .set("deps", vec![root_id])
        )
        .unwrap();
        loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            let msg = Json::parse(&line).unwrap();
            if msg.get("type").unwrap().as_str().unwrap() == "error" {
                break;
            }
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn uds_cancel_verb_aborts_and_frees_the_generation() {
        let path = start_server("cancel");
        let stream = UnixStream::connect(&path).unwrap();
        let mut out = stream.try_clone().unwrap();
        // a generation long enough that the cancel always lands first
        writeln!(
            out,
            "{}",
            Json::obj()
                .set("type", "generate")
                .set("prompt", vec![1i32; 64])
                .set("max_new_tokens", 200_000usize)
        )
        .unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let acc = Json::parse(&line).unwrap();
        assert_eq!(acc.get("type").unwrap().as_str().unwrap(), "accepted");
        let id = acc.get("id").unwrap().as_usize().unwrap();
        writeln!(out, "{}", Json::obj().set("type", "cancel").set("id", id)).unwrap();
        // read until the terminal frame: it must be done.cancelled
        loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            let msg = Json::parse(&line).unwrap();
            match msg.get("type").unwrap().as_str().unwrap() {
                "done.cancelled" => {
                    assert_eq!(msg.get("id").unwrap().as_usize().unwrap(), id);
                    break;
                }
                "done" => panic!("generation finished before the cancel landed"),
                _ => {} // token / cancel.ack frames
            }
        }
        // the connection (and the server) keep working afterwards
        let (toks, _, _) = client_generate(&path, &[1, 2, 3], Priority::Reactive, 2).unwrap();
        assert_eq!(toks.len(), 2);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn uds_stats_reports_accumulated_serving_counters() {
        let path = start_server("stats");
        let _ = client_generate(&path, &[1, 2, 3, 4], Priority::Reactive, 3).unwrap();
        let stream = UnixStream::connect(&path).unwrap();
        let mut out = stream.try_clone().unwrap();
        writeln!(out, "{}", Json::obj().set("type", "stats")).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let msg = Json::parse(&line).unwrap();
        assert_eq!(msg.get("type").unwrap().as_str().unwrap(), "stats");
        assert!(msg.get("served").unwrap().as_usize().unwrap() >= 1);
        assert!(msg.get("tokens").unwrap().as_usize().unwrap() >= 3);
        // the overload/recovery counters are part of the frame
        for key in ["rejected", "displaced", "shed", "parked", "resumed", "recovered"] {
            assert_eq!(msg.get(key).unwrap().as_usize().unwrap(), 0, "{key}");
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn uds_empty_prompt_is_error() {
        let path = start_server("empty");
        let err = client_generate(&path, &[], Priority::Reactive, 3);
        assert!(err.is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn uds_overloaded_server_sends_retry_after() {
        let mut geo = llama32_3b();
        geo.n_layers = 2;
        let bridge = Arc::new(ExecBridge::synthetic(geo));
        let path = tmp_socket("overload");
        let overload = OverloadConfig { max_queue_depth: 1, ..OverloadConfig::default() };
        let server = Server::with_options(
            bridge,
            &path,
            default_soc(),
            SchedulerConfig::default(),
            "agent-xpu",
            overload,
            None,
        )
        .unwrap();
        let p = path.clone();
        std::thread::spawn(move || {
            let _ = server.run();
        });
        for _ in 0..200 {
            if p.exists() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        // occupy the single slot with an endless REACTIVE generation
        // (reactive work is never shed, so the queue stays full)
        let stream = UnixStream::connect(&path).unwrap();
        let mut out = stream.try_clone().unwrap();
        writeln!(
            out,
            "{}",
            Json::obj()
                .set("type", "generate")
                .set("prompt", vec![1i32; 64])
                .set("max_new_tokens", 200_000usize)
        )
        .unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let acc = Json::parse(&line).unwrap();
        assert_eq!(acc.get("type").unwrap().as_str().unwrap(), "accepted");
        let id = acc.get("id").unwrap().as_usize().unwrap();
        // a second proactive call is refused with a machine-readable
        // retry hint (the client helper surfaces it as an error)
        let err = client_generate_session(
            &path,
            None,
            &[2, 2, 2],
            Priority::Proactive,
            4,
        )
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("overloaded"),
            "expected an overloaded retry-after, got: {err:#}"
        );
        writeln!(out, "{}", Json::obj().set("type", "cancel").set("id", id)).unwrap();
        let _ = std::fs::remove_file(path);
    }
}
