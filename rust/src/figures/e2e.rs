//! End-to-end figures: the Fig. 4 scheme comparison, Fig. 6
//! (proactive-only), Fig. 7 (proactive-reactive mixed), and the design
//! ablations.  All runs are timing-only DES at the paper's Llama-3.2-3B
//! scale with seeded workload traces.

use anyhow::Result;

use crate::baselines::CpuFcfsEngine;
use crate::config::{ModelGeometry, SchedulerConfig, SocConfig, llama32_3b};
use crate::coordinator::AgentXpuEngine;
use crate::engine::{EngineCore, registry};
use crate::metrics::RunReport;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::workload::{
    FlowSpec, Priority, Request, WorkloadSpec, flatten_flows, flow_trace, merge_traces,
    proactive_trace, profile, reactive_trace,
};

fn geo_for_sweeps() -> ModelGeometry {
    llama32_3b()
}

/// Build the paper's mixed workload: proactive Poisson streams sampled
/// across the three proactive profiles + one reactive stream.
pub fn mixed_trace(
    proactive_rate: f64,
    reactive_interval_s: f64,
    duration_s: f64,
    seed: u64,
    geo: &ModelGeometry,
) -> Vec<Request> {
    let mut streams = vec![];
    let pro_profiles = ["proactivebench", "samsum", "cnn_dailymail"];
    for (i, name) in pro_profiles.iter().enumerate() {
        streams.push(proactive_trace(
            &WorkloadSpec {
                profile: profile(name).unwrap(),
                rate_per_s: proactive_rate / pro_profiles.len() as f64,
                duration_s,
                seed: seed + i as u64,
                max_seq: geo.max_seq,
            },
            geo.vocab,
            (i as u64 + 1) * 1_000_000,
        ));
    }
    if reactive_interval_s > 0.0 {
        streams.push(reactive_trace(
            &WorkloadSpec {
                profile: profile("lmsys").unwrap(),
                rate_per_s: 1.0 / reactive_interval_s,
                duration_s,
                seed: seed + 100,
                max_seq: geo.max_seq,
            },
            geo.vocab,
            9_000_000,
        ));
    }
    merge_traces(streams)
}

fn report_row(rep: &RunReport) -> (f64, f64, f64, f64) {
    let r = rep.class(Priority::Reactive);
    let p = rep.class(Priority::Proactive);
    (
        r.mean_norm_latency_ms,
        p.mean_norm_latency_ms,
        p.tokens_per_s,
        rep.joules_per_token(),
    )
}

/// Fig. 4: one long proactive task + one reactive arrival under
/// *every registered policy* (the paper's four co-scheduling schemes
/// plus whatever else the registry knows — `cpu-fcfs`, `deadline`, and
/// any future entry run automatically).  Prints reactive latency,
/// proactive completion, makespan, and an ASCII Gantt per policy.
/// `fig schemes --smoke` in CI exercises this as the end-to-end check
/// that every registry policy still builds, runs, and traces.
pub fn fig_schemes(soc: &SocConfig) -> Result<Json> {
    let geo = geo_for_sweeps();
    let trace = || {
        vec![
            Request {
                id: 1,
                priority: Priority::Proactive,
                arrival_us: 0.0,
                prompt: vec![1; 1536],
                max_new_tokens: 48,
                profile: "proactivebench".into(),
                flow: None,
            },
            Request {
                id: 2,
                priority: Priority::Reactive,
                arrival_us: 150_000.0,
                prompt: vec![1; 512],
                max_new_tokens: 32,
                profile: "lmsys".into(),
                flow: None,
            },
        ]
    };

    let mut rows = vec![];
    let mut table = Table::new(&[
        "scheme", "reactive TTFT (ms)", "reactive e2e (ms)",
        "proactive e2e (ms)", "makespan (ms)",
    ]);
    let xpu_names: Vec<&str> = soc.xpus.iter().map(|x| x.name.as_str()).collect();
    let mut gantts = String::new();

    let mut run_one = |label: &str,
                       rep: RunReport,
                       gantt: Option<String>|
     -> Result<()> {
        let rt = rep.reqs.iter().find(|m| m.id == 2).unwrap();
        let pro = rep.reqs.iter().find(|m| m.id == 1).unwrap();
        table.row(vec![
            label.to_string(),
            format!("{:.1}", rt.ttft_us().unwrap() / 1e3),
            format!("{:.1}", rt.e2e_us().unwrap() / 1e3),
            format!("{:.1}", pro.e2e_us().unwrap() / 1e3),
            format!("{:.1}", rep.makespan_us / 1e3),
        ]);
        rows.push(
            Json::obj()
                .set("scheme", label)
                .set("reactive_ttft_ms", rt.ttft_us().unwrap() / 1e3)
                .set("reactive_e2e_ms", rt.e2e_us().unwrap() / 1e3)
                .set("proactive_e2e_ms", pro.e2e_us().unwrap() / 1e3)
                .set("makespan_ms", rep.makespan_us / 1e3),
        );
        if let Some(g) = gantt {
            gantts.push_str(&format!("\n[{label}]\n{g}"));
        }
        Ok(())
    };

    // Every registered policy runs the same two-request scenario —
    // the registry is the single list of comparison points.
    for name in registry::names() {
        let mut e =
            registry::build(name, geo.clone(), soc.clone(), SchedulerConfig::default())?;
        let rep = e.run(trace())?;
        let g = e.last_trace().map(|t| t.gantt(&xpu_names, 72));
        let label = rep.engine.clone();
        run_one(&label, rep, g)?;
    }

    println!("\n== fig-schemes: proactive-reactive co-scheduling (Fig. 4) ==");
    table.print();
    println!("{gantts}\n(R = reactive kernel, p = proactive kernel)");
    Ok(Json::obj().set("figure", "schemes").set("rows", Json::Arr(rows)))
}

/// Fig. 6: proactive-only workloads — normalized latency vs request
/// rate, Agent.xpu vs the llama.cpp-like baseline, per workload.
pub fn fig_proactive(
    soc: &SocConfig,
    rates: &[f64],
    duration_s: f64,
    seed: u64,
) -> Result<Json> {
    let geo = geo_for_sweeps();
    let mut rows = vec![];
    let mut table = Table::new(&[
        "workload", "rate(req/s)",
        "agent.xpu norm-lat (ms/tok)", "llama.cpp norm-lat (ms/tok)",
        "agent.xpu tok/s", "llama.cpp tok/s",
        "agent.xpu J/tok", "llama.cpp J/tok",
    ]);
    for name in ["proactivebench", "samsum", "cnn_dailymail"] {
        for &rate in rates {
            let spec = WorkloadSpec {
                profile: profile(name).unwrap(),
                rate_per_s: rate,
                duration_s,
                seed,
                max_seq: geo.max_seq,
            };
            let trace = proactive_trace(&spec, geo.vocab, 1);
            if trace.is_empty() {
                continue;
            }
            let mut ax = AgentXpuEngine::synthetic(
                geo.clone(),
                soc.clone(),
                SchedulerConfig::default(),
            );
            let ra = ax.run(trace.clone())?;
            let mut lc = CpuFcfsEngine::new(geo.clone(), soc.clone(), 4);
            let rl = lc.run(trace)?;
            let (_, pa, ta, ja) = report_row(&ra);
            let (_, pl, tl, jl) = report_row(&rl);
            table.row(vec![
                name.into(),
                format!("{rate:.2}"),
                format!("{pa:.1}"),
                format!("{pl:.1}"),
                format!("{ta:.1}"),
                format!("{tl:.1}"),
                format!("{ja:.2}"),
                format!("{jl:.2}"),
            ]);
            rows.push(
                Json::obj()
                    .set("workload", name)
                    .set("rate", rate)
                    .set("agent_norm_ms", Json::num_or_null(pa))
                    .set("llamacpp_norm_ms", Json::num_or_null(pl))
                    .set("agent_tok_s", ta)
                    .set("llamacpp_tok_s", tl)
                    .set("agent_j_tok", ja)
                    .set("llamacpp_j_tok", jl)
                    .set("agent_peak_w", ra.peak_power_w)
                    .set("llamacpp_peak_w", rl.peak_power_w),
            );
        }
    }
    println!("\n== fig-proactive: proactive-only workloads (Fig. 6) ==");
    table.print();
    Ok(Json::obj().set("figure", "proactive").set("rows", Json::Arr(rows)))
}

/// Fig. 7: mixed workloads — reactive + proactive normalized latency
/// across proactive rates × reactive intervals, both engines.
pub fn fig_mixed(
    soc: &SocConfig,
    reactive_intervals_s: &[f64],
    proactive_rates: &[f64],
    duration_s: f64,
    seed: u64,
) -> Result<Json> {
    let geo = geo_for_sweeps();
    let mut rows = vec![];
    let mut table = Table::new(&[
        "rt-interval(s)", "pro-rate(req/s)",
        "agent rt-lat", "llama.cpp rt-lat",
        "agent pro-lat", "llama.cpp pro-lat",
        "preempt", "backfill",
    ]);
    for &interval in reactive_intervals_s {
        for &rate in proactive_rates {
            let trace = mixed_trace(rate, interval, duration_s, seed, &geo);
            if trace.is_empty() {
                continue;
            }
            let mut ax = AgentXpuEngine::synthetic(
                geo.clone(),
                soc.clone(),
                SchedulerConfig::default(),
            );
            let ra = ax.run(trace.clone())?;
            let mut lc = CpuFcfsEngine::new(geo.clone(), soc.clone(), 4);
            let rl = lc.run(trace)?;
            let (ra_rt, ra_pro, _, _) = report_row(&ra);
            let (rl_rt, rl_pro, _, _) = report_row(&rl);
            table.row(vec![
                format!("{interval:.0}"),
                format!("{rate:.2}"),
                format!("{ra_rt:.1}"),
                format!("{rl_rt:.1}"),
                format!("{ra_pro:.1}"),
                format!("{rl_pro:.1}"),
                format!("{}", ra.preemptions),
                format!("{}", ra.backfills),
            ]);
            rows.push(
                Json::obj()
                    .set("reactive_interval_s", interval)
                    .set("proactive_rate", rate)
                    .set("agent_reactive_norm_ms", Json::num_or_null(ra_rt))
                    .set("llamacpp_reactive_norm_ms", Json::num_or_null(rl_rt))
                    .set("agent_proactive_norm_ms", Json::num_or_null(ra_pro))
                    .set("llamacpp_proactive_norm_ms", Json::num_or_null(rl_pro))
                    .set("agent_preemptions", ra.preemptions as usize)
                    .set("agent_backfills", ra.backfills as usize)
                    .set("agent_j_tok", ra.joules_per_token())
                    .set("llamacpp_j_tok", rl.joules_per_token()),
            );
        }
    }
    println!("\n== fig-mixed: proactive-reactive co-existence (Fig. 7) ==");
    println!("(norm-lat = mean TTFT / input length, ms/token)");
    table.print();
    Ok(Json::obj().set("figure", "mixed").set("rows", Json::Arr(rows)))
}

/// Build a mixed *flow* workload: reactive multi-turn chat sessions
/// (lmsys-shaped, user think-time between turns) + proactive monitor
/// flows (proactivebench-shaped, event-driven wake-ups into a growing
/// context).
pub fn flow_trace_mixed(
    chat_rate: f64,
    monitor_rate: f64,
    duration_s: f64,
    seed: u64,
    geo: &ModelGeometry,
) -> Vec<Request> {
    let chats = flow_trace(
        &FlowSpec {
            profile: profile("lmsys").unwrap(),
            flow_rate_per_s: chat_rate,
            think_time_s: 8.0,
            turns: (2, 5),
            duration_s,
            seed,
            max_seq: geo.max_seq,
        },
        Priority::Reactive,
        geo.vocab,
        0,
        0,
    );
    let n_chat_reqs: u64 = chats.iter().map(|f| f.total_turns() as u64).sum();
    let n_chat_flows = chats.len() as u64;
    let monitors = flow_trace(
        &FlowSpec {
            profile: profile("proactivebench").unwrap(),
            flow_rate_per_s: monitor_rate,
            think_time_s: 20.0,
            turns: (2, 4),
            duration_s,
            seed: seed + 1,
            max_seq: geo.max_seq,
        },
        Priority::Proactive,
        geo.vocab,
        n_chat_reqs,
        n_chat_flows,
    );
    let mut all = flatten_flows(chats);
    all.extend(flatten_flows(monitors));
    merge_traces(vec![all])
}

/// Flow-level sessions: multi-turn chat + monitor flows under the
/// Agent.xpu engine (cross-turn KV reuse) vs the single-XPU
/// continuous-batching scheme and the llama.cpp-like baseline (both
/// full-prefix recompute) — quantifies the delta-prefill win per
/// engine: per-flow e2e latency, per-turn TTFT, prefix-cache hit-rate,
/// and reused vs recomputed prefill tokens.
pub fn fig_flows(soc: &SocConfig, duration_s: f64, seed: u64) -> Result<Json> {
    // undefined means (no flows in a short trace) serialize as null,
    // never as a bare NaN the results file's consumers would choke on
    let num_or_null = Json::num_or_null;
    let geo = geo_for_sweeps();
    let trace = flow_trace_mixed(0.06, 0.04, duration_s, seed, &geo);
    let mut rows = vec![];
    let mut table = Table::new(&[
        "engine", "flows", "flow e2e (ms)", "turn TTFT (ms)",
        "hit-rate", "reused tok", "recomputed tok",
    ]);
    let mut engines: Vec<Box<dyn EngineCore + Send>> = ["agent-xpu", "scheme-c", "cpu-fcfs"]
        .iter()
        .map(|n| registry::build(n, geo.clone(), soc.clone(), SchedulerConfig::default()))
        .collect::<Result<_>>()?;
    for e in engines.iter_mut() {
        let rep = e.run(trace.clone())?;
        let flows = rep.flows();
        let turn_ttft = {
            let ts: Vec<f64> = flows.iter().map(|f| f.mean_turn_ttft_ms).collect();
            if ts.is_empty() { f64::NAN } else { ts.iter().sum::<f64>() / ts.len() as f64 }
        };
        table.row(vec![
            rep.engine.clone(),
            format!("{}", flows.len()),
            format!("{:.1}", rep.mean_flow_e2e_ms()),
            format!("{turn_ttft:.1}"),
            format!("{:.2}", rep.prefix_cache_hit_rate()),
            format!("{}", rep.reused_prefix_tokens()),
            format!("{}", rep.recomputed_prefill_tokens()),
        ]);
        rows.push(
            Json::obj()
                .set("engine", rep.engine.as_str())
                .set("flows", flows.len())
                .set("mean_flow_e2e_ms", num_or_null(rep.mean_flow_e2e_ms()))
                .set("mean_turn_ttft_ms", num_or_null(turn_ttft))
                .set("prefix_cache_hit_rate", num_or_null(rep.prefix_cache_hit_rate()))
                .set("reused_prefix_tokens", rep.reused_prefix_tokens())
                .set("recomputed_prefill_tokens", rep.recomputed_prefill_tokens()),
        );
    }
    println!("\n== fig-flows: multi-turn flows & cross-turn KV reuse ==");
    println!("(flow e2e includes user think-time; hit-rate over continuation turns)");
    table.print();
    Ok(Json::obj().set("figure", "flows").set("rows", Json::Arr(rows)))
}

/// Design ablations (DESIGN.md §4): toggle each §5/§6 mechanism and
/// measure reactive latency + proactive throughput on a mixed load.
pub fn fig_ablation(soc: &SocConfig, duration_s: f64, seed: u64) -> Result<Json> {
    let geo = geo_for_sweeps();
    let trace = mixed_trace(1.5, 12.0, duration_s, seed, &geo);
    let variants: Vec<(&str, SchedulerConfig)> = vec![
        ("full", SchedulerConfig::default()),
        ("no-backfill", SchedulerConfig { backfill: false, ..Default::default() }),
        ("no-preemption", SchedulerConfig { preemption: false, ..Default::default() }),
        ("no-disaggregation", SchedulerConfig { disaggregation: false, ..Default::default() }),
        (
            "no-contention-policy",
            // collapse the tiers: everything launches aggressively
            SchedulerConfig { pressure_low: 1e9, pressure_high: 1e9, ..Default::default() },
        ),
        ("b_max=1", SchedulerConfig { b_max: 1, ..Default::default() }),
        (
            "chunk<=64",
            SchedulerConfig { chunk_latency_budget_ms: 2.0, ..Default::default() },
        ),
    ];
    let mut rows = vec![];
    let mut table = Table::new(&[
        "variant", "reactive norm-lat (ms/tok)", "proactive tok/s",
        "preempt", "backfill", "J/tok",
    ]);
    for (label, sched) in variants {
        let mut e = AgentXpuEngine::synthetic(geo.clone(), soc.clone(), sched);
        let rep = e.run(trace.clone())?;
        let (rt, _, pt, j) = report_row(&rep);
        table.row(vec![
            label.into(),
            format!("{rt:.1}"),
            format!("{pt:.1}"),
            format!("{}", rep.preemptions),
            format!("{}", rep.backfills),
            format!("{j:.2}"),
        ]);
        rows.push(
            Json::obj()
                .set("variant", label)
                .set("reactive_norm_ms", Json::num_or_null(rt))
                .set("proactive_tok_s", pt)
                .set("preemptions", rep.preemptions as usize)
                .set("backfills", rep.backfills as usize)
                .set("j_per_tok", j),
        );
    }
    println!("\n== fig-ablation: design-choice ablations ==");
    table.print();
    Ok(Json::obj().set("figure", "ablation").set("rows", Json::Arr(rows)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;

    #[test]
    fn schemes_reproduce_fig4_ordering() {
        let j = fig_schemes(&default_soc()).unwrap();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        let get = |s: &str, k: &str| {
            rows.iter()
                .find(|r| r.get("scheme").unwrap().as_str().unwrap().contains(s))
                .unwrap()
                .get(k)
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // (d) achieves the lowest reactive latency...
        let d_rt = get("agent.xpu", "reactive_ttft_ms");
        for s in ["scheme-b", "scheme-c"] {
            assert!(d_rt <= get(s, "reactive_ttft_ms") * 1.05, "{s}");
        }
        // ...and the shortest makespan (highest system throughput)
        let d_mk = get("agent.xpu", "makespan_ms");
        for s in ["scheme-a", "scheme-b", "scheme-c"] {
            assert!(d_mk <= get(s, "makespan_ms"), "{s}");
        }
    }

    #[test]
    fn mixed_trace_is_mixed_and_seeded() {
        let geo = llama32_3b();
        let t1 = mixed_trace(1.0, 10.0, 60.0, 7, &geo);
        let t2 = mixed_trace(1.0, 10.0, 60.0, 7, &geo);
        assert_eq!(t1.len(), t2.len());
        assert!(t1.iter().any(|r| r.priority == Priority::Reactive));
        assert!(t1.iter().any(|r| r.priority == Priority::Proactive));
    }

    #[test]
    fn flow_trace_mixed_has_both_flow_classes_and_unique_ids() {
        let geo = llama32_3b();
        let t = flow_trace_mixed(0.08, 0.05, 120.0, 7, &geo);
        assert!(t.iter().any(|r| r.priority == Priority::Reactive && r.flow.is_some()));
        assert!(t.iter().any(|r| r.priority == Priority::Proactive && r.flow.is_some()));
        let mut ids: Vec<u64> = t.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), t.len(), "request ids unique across flow streams");
        let mut fids: Vec<(u64, usize)> = t
            .iter()
            .filter_map(|r| r.flow.as_ref().map(|f| (f.flow_id, f.turn_idx)))
            .collect();
        fids.sort_unstable();
        fids.dedup();
        assert_eq!(fids.len(), t.len(), "(flow, turn) pairs unique");
    }

    #[test]
    fn fig_flows_agent_engine_wins_on_reuse() {
        let j = fig_flows(&default_soc(), 90.0, 7).unwrap();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        let get = |s: &str, k: &str| {
            rows.iter()
                .find(|r| r.get("engine").unwrap().as_str().unwrap().contains(s))
                .unwrap()
                .get(k)
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // the acceptance criterion: Agent.xpu reuses cross-turn KV —
        // fewer recomputed prefill tokens and a real hit-rate — while
        // the single-XPU baseline recomputes every conversation prefix
        assert!(get("agent.xpu", "prefix_cache_hit_rate") > 0.5);
        assert_eq!(get("scheme-c", "reused_prefix_tokens"), 0.0);
        assert!(
            get("agent.xpu", "recomputed_prefill_tokens")
                < get("scheme-c", "recomputed_prefill_tokens")
        );
        // ... and turns that skip their prefix finish their flows sooner
        assert!(
            get("agent.xpu", "mean_flow_e2e_ms") <= get("scheme-c", "mean_flow_e2e_ms")
        );
    }
}
