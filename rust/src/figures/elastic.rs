//! Elastic-binding ablation (§5.2): runtime re-planning — folding
//! margins back to the NPU and splitting head chunks across NPU+iGPU
//! mid-flight — against the best *static* chunk-to-XPU binding the
//! paper's scheme (a)/(b)/(c) baselines represent.
//!
//! Two scenarios, same seeded mixed agentic trace:
//!
//! - `mixed`: no display workload; splits fire when reactive prefill
//!   pins the NPU and the co-run model predicts an iGPU slice wins.
//! - `graphics`: a 60 Hz display renders on the iGPU and the elastic
//!   engine yields to vsync (`yield_to_graphics`) — margin folds to
//!   the NPU keep the prefill pipeline moving through the vetoes.
//!   The knob is inert for the static baselines, which never consult
//!   the duty governor (they hold whatever binding they started with).
//!
//! Reported per run: reactive p99/mean TTFT, makespan, the elastic
//! counters (`rebinds`/`splits`/`split_tokens`), backfills, and frame
//! deadline statistics.  The pinned acceptance claim: the elastic
//! engine beats the best static scheme on reactive p99 TTFT and on
//! makespan in *both* scenarios, and actually re-binds somewhere.

use anyhow::Result;

use crate::config::{SchedulerConfig, SocConfig, llama32_3b};
use crate::engine::{EngineCore, registry};
use crate::metrics::{RunReport, percentile};
use crate::soc::GraphicsConfig;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::workload::Priority;

use super::mixed_trace;

/// The elastic engine vs the static-binding schemes of Fig. 4.
const ENGINES: [&str; 4] = ["agent-xpu", "scheme-a", "scheme-b", "scheme-c"];

/// Reactive p99 TTFT (ms) over finished reactive requests — the SLO
/// tail the elastic re-binding protects.  NaN when none finished.
fn reactive_p99_ttft_ms(rep: &RunReport) -> f64 {
    let mut ttfts: Vec<f64> = rep
        .reqs
        .iter()
        .filter(|m| m.priority == Priority::Reactive && !m.tool)
        .filter_map(|m| m.ttft_us().map(|t| t / 1e3))
        .collect();
    ttfts.sort_by(|a, b| a.total_cmp(b));
    percentile(&ttfts, 0.99)
}

fn elastic_row(rep: &RunReport, engine: &str, scenario: &str) -> Json {
    let r = rep.class(Priority::Reactive);
    let p = rep.class(Priority::Proactive);
    Json::obj()
        .set("engine", engine)
        .set("label", rep.engine.as_str())
        .set("scenario", scenario)
        .set("reactive_p99_ttft_ms", Json::num_or_null(reactive_p99_ttft_ms(rep)))
        .set("reactive_mean_ttft_ms", Json::num_or_null(r.mean_ttft_ms))
        .set("proactive_tok_s", p.tokens_per_s)
        .set("makespan_s", rep.makespan_us / 1e6)
        .set("rebinds", rep.rebinds as usize)
        .set("splits", rep.splits as usize)
        .set("split_tokens", rep.split_tokens as usize)
        .set("backfills", rep.backfills as usize)
        .set("preemptions", rep.preemptions as usize)
        .set("frames_scheduled", rep.frames_scheduled as usize)
        .set("frames_missed", rep.frames_missed as usize)
        .set("frame_miss_rate", rep.frame_miss_rate())
}

/// The elastic-vs-static ablation: every engine serves the same mixed
/// trace twice — bare, then against a 60 Hz display with the elastic
/// engine yielding to vsync.
pub fn fig_elastic(soc: &SocConfig, duration_s: f64, seed: u64) -> Result<Json> {
    let geo = llama32_3b();
    // loaded enough that binding choices show up in the tail: a steady
    // proactive stream plus a chatty reactive one
    let trace = mixed_trace(1.0, 2.0, duration_s, seed, &geo);

    let mut rows = vec![];
    let mut table = Table::new(&[
        "engine", "scenario", "rt p99 TTFT (ms)", "makespan (s)",
        "rebinds", "splits", "split-tok", "missed",
    ]);
    for (scenario, gfx) in [("mixed", None), ("graphics", Some(GraphicsConfig::default()))]
    {
        for engine in ENGINES {
            let mut sched = SchedulerConfig::default();
            // under a display, the elastic engine yields the iGPU to
            // vsync and re-binds squeezed margins to the NPU; static
            // baselines never consult the governor, so the knob is
            // inert for them
            sched.yield_to_graphics = gfx.is_some();
            let mut e = registry::build(engine, geo.clone(), soc.clone(), sched)?;
            e.set_graphics(gfx.clone());
            let rep = e.run(trace.clone())?;
            table.row(vec![
                rep.engine.clone(),
                scenario.into(),
                format!("{:.1}", reactive_p99_ttft_ms(&rep)),
                format!("{:.2}", rep.makespan_us / 1e6),
                format!("{}", rep.rebinds),
                format!("{}", rep.splits),
                format!("{}", rep.split_tokens),
                format!("{}", rep.frames_missed),
            ]);
            rows.push(elastic_row(&rep, engine, scenario));
        }
    }
    println!("\n== fig-elastic: runtime-elastic binding vs static schemes (§5.2) ==");
    println!("(splits co-run a head-chunk slice on the iGPU; folds re-bind margins to the NPU)");
    table.print();
    Ok(Json::obj().set("figure", "elastic").set("rows", Json::Arr(rows)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;

    /// The acceptance criterion end-to-end: strictly parseable NaN-free
    /// JSON; the elastic engine at or below the best static scheme on
    /// reactive p99 TTFT and makespan in both scenarios; and the
    /// elastic machinery actually engaged (some rebind happened) while
    /// the static schemes never re-bind.
    #[test]
    fn elastic_figure_beats_best_static_binding() {
        let j = fig_elastic(&default_soc(), 12.0, 7).unwrap();
        let text = j.to_string();
        assert!(!text.contains("NaN"), "invalid JSON token leaked: {text}");
        let back = Json::parse(&text).expect("figure output must parse");
        let rows = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2 * ENGINES.len());
        let get = |engine: &str, scenario: &str, k: &str| -> f64 {
            rows.iter()
                .find(|r| {
                    r.get("engine").unwrap().as_str().unwrap() == engine
                        && r.get("scenario").unwrap().as_str().unwrap() == scenario
                })
                .unwrap_or_else(|| panic!("row {engine}/{scenario}"))
                .get(k)
                .unwrap()
                .as_f64()
                .unwrap()
        };
        for scenario in ["mixed", "graphics"] {
            let best_static = |k: &str| {
                ["scheme-a", "scheme-b", "scheme-c"]
                    .iter()
                    .map(|s| get(s, scenario, k))
                    .fold(f64::INFINITY, f64::min)
            };
            // the paper's Fig. 4 ordering, held under elastic binding:
            // at-or-below the best static scheme's reactive tail (same
            // 5% slack as the schemes figure) and its makespan
            let p99 = get("agent-xpu", scenario, "reactive_p99_ttft_ms");
            assert!(
                p99 <= best_static("reactive_p99_ttft_ms") * 1.05,
                "{scenario}: elastic p99 TTFT {p99} vs static {}",
                best_static("reactive_p99_ttft_ms")
            );
            let mk = get("agent-xpu", scenario, "makespan_s");
            assert!(
                mk <= best_static("makespan_s"),
                "{scenario}: elastic makespan {mk} vs static {}",
                best_static("makespan_s")
            );
            // static bindings never re-bind, by construction
            for s in ["scheme-a", "scheme-b", "scheme-c"] {
                assert_eq!(get(s, scenario, "rebinds"), 0.0, "{s} must stay static");
            }
        }
        // the elastic machinery engaged somewhere across the scenarios
        let total_rebinds = get("agent-xpu", "mixed", "rebinds")
            + get("agent-xpu", "graphics", "rebinds");
        assert!(total_rebinds > 0.0, "no rebind ever fired — elastic path inert");
    }
}
