//! §3.1 micro-analyses: op-XPU affinity roofline, memory-contention
//! (Fig. 3), and §3.2 batching effects.  These exercise the SoC
//! substrate directly (no request scheduling) — they are the calibration
//! checks that the virtual SoC reproduces the paper's measured shapes.

use crate::config::{SocConfig, llama32_3b};
use crate::model::{decode_iter_cost, gemm_cost, gemv_cost, mha_cost, prefill_layer_cost};
use crate::soc::{KernelClass, LaunchSpec, SocSim, XpuModel};
use crate::util::bench::Table;
use crate::util::json::Json;

/// Op-XPU affinity roofline (§3.1): GEMM vs GQA-MHA throughput and
/// energy efficiency on NPU/iGPU across sequence lengths, with the
/// NPU's amortized JIT cost charged to dynamic kernels.
pub fn fig_affinity(soc: &SocConfig) -> Json {
    let geo = llama32_3b();
    let npu = XpuModel::new(soc.xpu("npu").unwrap().clone());
    let igpu = XpuModel::new(soc.xpu("igpu").unwrap().clone());
    let mut rows = vec![];
    let mut table = Table::new(&[
        "op", "seqlen", "AI (flop/B)",
        "npu TFLOPS", "npu TFLOPS/W", "igpu TFLOPS", "igpu TFLOPS/W",
    ]);
    let seqs = [64usize, 128, 256, 512, 1024, 2048, 4096];
    for &k in &seqs {
        // the paper's GEMM shape: Y[k,M] = X[k,D] @ W[D,M], D=M=4096
        let g = gemm_cost(k, 4096, 4096);
        // GQA MHA: hd=128, 32 Q heads, 8 KV heads (paper's profile)
        let mut mg = geo.clone();
        mg.n_q_heads = 32;
        mg.n_kv_heads = 8;
        mg.head_dim = 128;
        let m = mha_cost(&mg, k, k);
        for (op, c) in [("gemm", g), ("mha", m)] {
            let row = Json::obj()
                .set("op", op)
                .set("seqlen", k)
                .set("ai", c.arithmetic_intensity())
                .set("npu_tflops", npu.achieved_tflops(&c))
                .set("npu_tflops_w", npu.tflops_per_watt(&c))
                .set("igpu_tflops", igpu.achieved_tflops(&c))
                .set("igpu_tflops_w", igpu.tflops_per_watt(&c));
            table.row(vec![
                op.into(),
                k.to_string(),
                format!("{:.1}", c.arithmetic_intensity()),
                format!("{:.2}", npu.achieved_tflops(&c)),
                format!("{:.3}", npu.tflops_per_watt(&c)),
                format!("{:.2}", igpu.achieved_tflops(&c)),
                format!("{:.3}", igpu.tflops_per_watt(&c)),
            ]);
            rows.push(row);
        }
    }
    println!("\n== fig-affinity: op-XPU roofline (§3.1) ==");
    table.print();
    Json::obj().set("figure", "affinity").set("rows", Json::Arr(rows))
}

/// Fig. 3: execution-time stretch + achieved DDR bandwidth when NPU and
/// iGPU kernels run standalone vs co-executed, for all four
/// GEMM/GEMV pairings.
pub fn fig_contention(soc: &SocConfig) -> Json {
    // the paper's op shapes: (k,M,D) = (4096,4096,4096) GEMM,
    // (1,4096,4096) GEMV — scaled up so kernels are long enough to
    // overlap fully
    let ops: [(&str, crate::model::KernelCost); 2] = [
        ("gemm", gemm_cost(4096, 4096, 4096)),
        ("gemv", gemv_cost(8192, 8192)),
    ];
    let mut rows = vec![];
    let mut table = Table::new(&[
        "npu op", "igpu op",
        "npu standalone(ms)", "npu coexec(ms)", "npu stretch",
        "igpu standalone(ms)", "igpu coexec(ms)", "igpu stretch",
        "ddr BW (GB/s)",
    ]);
    for (na, ca) in &ops {
        for (nb, cb) in &ops {
            // standalone timings
            let mut sim = SocSim::new(soc);
            let (npu, igpu) =
                (sim.xpu_index("npu").unwrap(), sim.xpu_index("igpu").unwrap());
            let ta = sim.xpus[npu].timing(ca);
            let tb = sim.xpus[igpu].timing(cb);
            // co-execute: launch repeatedly within a window (paper
            // methodology) — here both start together; the arbiter
            // stretches memory phases exactly
            sim.launch(npu, LaunchSpec { timing: ta, class: KernelClass::Proactive });
            sim.launch(igpu, LaunchSpec { timing: tb, class: KernelClass::Proactive });
            let mut done = vec![];
            while sim.next_event_in().is_some() {
                done.extend(sim.advance_until(sim.now_us + 1e12));
            }
            let find = |x: usize| {
                done.iter()
                    .find(|c| c.xpu == x)
                    .map(|c| c.finished_us - c.started_us)
                    .unwrap()
            };
            let (ca_ms, cb_ms) = (find(npu) / 1e3, find(igpu) / 1e3);
            let (sa_ms, sb_ms) = (ta.nominal_us / 1e3, tb.nominal_us / 1e3);
            let bw = sim.mean_bandwidth_gbps();
            table.row(vec![
                na.to_string(),
                nb.to_string(),
                format!("{sa_ms:.2}"),
                format!("{ca_ms:.2}"),
                format!("{:.2}x", ca_ms / sa_ms),
                format!("{sb_ms:.2}"),
                format!("{cb_ms:.2}"),
                format!("{:.2}x", cb_ms / sb_ms),
                format!("{bw:.1}"),
            ]);
            rows.push(
                Json::obj()
                    .set("npu_op", *na)
                    .set("igpu_op", *nb)
                    .set("npu_standalone_ms", sa_ms)
                    .set("npu_coexec_ms", ca_ms)
                    .set("igpu_standalone_ms", sb_ms)
                    .set("igpu_coexec_ms", cb_ms)
                    .set("mean_bw_gbps", bw),
            );
        }
    }
    println!("\n== fig-contention: NPU/iGPU co-execution (Fig. 3) ==");
    table.print();
    Json::obj().set("figure", "contention").set("rows", Json::Arr(rows))
}

/// §3.2 batching effects on one accelerator: prefill batches scale
/// ~linearly in latency (the accelerator is already saturated), decode
/// batches are ~flat, and decode batched *with* a prefill suffers badly.
pub fn fig_batching(soc: &SocConfig) -> Json {
    let geo = llama32_3b();
    let igpu = XpuModel::new(soc.xpu("igpu").unwrap().clone());
    let chunk = 256usize;
    let ctx = 512usize;
    let mut rows = vec![];
    let mut table = Table::new(&[
        "batch", "prefill batch (ms)", "decode batch (ms)", "decode + 1 prefill (ms)",
    ]);
    let prefill_one: f64 = (0..geo.n_layers)
        .map(|_| igpu.timing(&prefill_layer_cost(&geo, chunk, chunk, 0, false)).nominal_us)
        .sum();
    for b in [1usize, 2, 4, 8] {
        // batching b prefills on one XPU ≈ serial chunks (saturated)
        let pre_ms = prefill_one * b as f64 / 1e3;
        let dec_ms = igpu.timing(&decode_iter_cost(&geo, b, ctx)).nominal_us / 1e3;
        // one full prefill joins the iteration: decode tokens wait for it
        let mixed_ms = dec_ms + prefill_one / 1e3;
        table.row(vec![
            b.to_string(),
            format!("{pre_ms:.1}"),
            format!("{dec_ms:.1}"),
            format!("{mixed_ms:.1}"),
        ]);
        rows.push(
            Json::obj()
                .set("batch", b)
                .set("prefill_batch_ms", pre_ms)
                .set("decode_batch_ms", dec_ms)
                .set("decode_with_prefill_ms", mixed_ms),
        );
    }
    println!("\n== fig-batching: batching effects on a single XPU (§3.2) ==");
    table.print();
    Json::obj().set("figure", "batching").set("rows", Json::Arr(rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;

    #[test]
    fn affinity_reproduces_paper_shape() {
        let j = fig_affinity(&default_soc());
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        // long-sequence GEMM: NPU is the efficiency king
        let gemm_long = rows
            .iter()
            .find(|r| {
                r.get("op").unwrap().as_str().unwrap() == "gemm"
                    && r.get("seqlen").unwrap().as_usize().unwrap() == 2048
            })
            .unwrap();
        assert!(
            gemm_long.get("npu_tflops_w").unwrap().as_f64().unwrap()
                > 3.0 * gemm_long.get("igpu_tflops_w").unwrap().as_f64().unwrap()
        );
        // MHA: iGPU wins raw throughput at any length (NPU pays JIT +
        // poor dynamic mapping)
        for r in rows.iter().filter(|r| r.get("op").unwrap().as_str().unwrap() == "mha") {
            assert!(
                r.get("igpu_tflops").unwrap().as_f64().unwrap()
                    > r.get("npu_tflops").unwrap().as_f64().unwrap(),
                "mha row {r}"
            );
        }
    }

    #[test]
    fn contention_reproduces_fig3_shape() {
        let j = fig_contention(&default_soc());
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        let get = |na: &str, nb: &str| {
            rows.iter()
                .find(|r| {
                    r.get("npu_op").unwrap().as_str().unwrap() == na
                        && r.get("igpu_op").unwrap().as_str().unwrap() == nb
                })
                .unwrap()
        };
        // GEMM+GEMM: co-execution latency-friendly (<5% stretch)
        let gg = get("gemm", "gemm");
        let stretch = gg.get("npu_coexec_ms").unwrap().as_f64().unwrap()
            / gg.get("npu_standalone_ms").unwrap().as_f64().unwrap();
        assert!(stretch < 1.05, "GEMM/GEMM stretch {stretch}");
        // GEMV+GEMV: both memory-bound → visible stretch
        let vv = get("gemv", "gemv");
        let stretch_n = vv.get("npu_coexec_ms").unwrap().as_f64().unwrap()
            / vv.get("npu_standalone_ms").unwrap().as_f64().unwrap();
        let stretch_i = vv.get("igpu_coexec_ms").unwrap().as_f64().unwrap()
            / vv.get("igpu_standalone_ms").unwrap().as_f64().unwrap();
        assert!(
            stretch_n.max(stretch_i) > 1.2,
            "GEMV/GEMV must stretch: {stretch_n} {stretch_i}"
        );
    }

    #[test]
    fn batching_reproduces_section32_shape() {
        let j = fig_batching(&default_soc());
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        let pre = |i: usize| rows[i].get("prefill_batch_ms").unwrap().as_f64().unwrap();
        let dec = |i: usize| rows[i].get("decode_batch_ms").unwrap().as_f64().unwrap();
        let mix = |i: usize| {
            rows[i].get("decode_with_prefill_ms").unwrap().as_f64().unwrap()
        };
        // prefill batch latency ∝ batch size (saturating)
        assert!(pre(3) / pre(0) > 6.0);
        // decode batch latency ~stable (well under linear)
        assert!(dec(3) / dec(0) < 2.5, "{} {}", dec(3), dec(0));
        // decode batched with prefill is far worse than decode alone
        assert!(mix(0) / dec(0) > 3.0);
    }
}
