//! Fleet figure (DESIGN.md §9): router × device-count sweep over the
//! multi-user fleet layer.
//!
//! Two seeded scenarios stress the two routing trade-offs:
//!
//! - **uniform** — many users, mild popularity skew, moderate load
//!   (~35% per-device duty).  Here session affinity dominates:
//!   `sticky-session` keeps every continuation on the device holding
//!   the flow's KV (warm delta prefill), while `random` re-routes
//!   turns blindly and pays full-conversation cache-cold prefills —
//!   sticky wins cache hit-rate and the reactive TTFT tail.
//! - **skewed** — one user per device, zipf-2.0 popularity, chat-only.
//!   The hot user alone demands ~4× one device's decode capacity, so
//!   pinning their flows (`sticky-session`) saturates a single device
//!   while the rest idle; `least-loaded` spreads turns by queue depth
//!   and duty, paying migration prefills to win makespan.
//!
//! `energy-budget` runs with a per-device joule budget calibrated from
//! the sticky baseline of the same (scenario, n) cell (a fraction of
//! its hottest device), so budget steering actually engages.  The
//! trace for a cell is identical across routers — only placement
//! differs.

use anyhow::Result;

use crate::config::{SocConfig, llama32_3b};
use crate::fleet::{Fleet, FleetConfig, FleetReport, route};
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::workload::{FleetSpec, fleet_user_flows};

/// Device counts swept by the full figure / the CI smoke run.
const FULL_COUNTS: &[usize] = &[4, 16, 64];
const SMOKE_COUNTS: &[usize] = &[2, 4];

const SCENARIOS: &[&str] = &["uniform", "skewed"];

/// Uniform scenario: simulated users per device, popularity skew, and
/// per-user flow-start rates (flows/s).  At ~0.4 turns/s per device
/// against ~0.75 turns/s of batched decode capacity the fleet runs
/// warm but unsaturated, so TTFT differences isolate cache warmth.
const UNIFORM_USERS_PER_DEVICE: usize = 3;
const UNIFORM_ZIPF: f64 = 0.4;
const UNIFORM_CHAT_RATE: f64 = 0.025;
const UNIFORM_MONITOR_RATE: f64 = 0.015;

/// Skewed scenario: one user per device at zipf 2.0, chat-only.  The
/// fleet-wide flow-start rate is split across users by the zipf
/// weights, which lands the hot user at ~1 flow/s (~4× one device's
/// decode capacity) regardless of fleet size.
const SKEW_ZIPF: f64 = 2.0;
const SKEW_FLEET_CHAT_RATE: f64 = 1.5;

/// Energy-budget calibration: budget = frac × the sticky baseline's
/// hottest-device energy for the same (scenario, n) cell.
const ENERGY_BUDGET_FRAC: f64 = 0.75;

fn scenario_spec(
    scenario: &str,
    n_devices: usize,
    duration_s: f64,
    seed: u64,
    max_seq: usize,
) -> FleetSpec {
    match scenario {
        "uniform" => FleetSpec {
            users: UNIFORM_USERS_PER_DEVICE * n_devices,
            zipf_exponent: UNIFORM_ZIPF,
            chat_rate_per_s: UNIFORM_CHAT_RATE,
            monitor_rate_per_s: UNIFORM_MONITOR_RATE,
            duration_s,
            seed: seed ^ 0x00f1_ee71,
            max_seq,
        },
        "skewed" => FleetSpec {
            users: n_devices,
            zipf_exponent: SKEW_ZIPF,
            chat_rate_per_s: SKEW_FLEET_CHAT_RATE / n_devices as f64,
            monitor_rate_per_s: 0.0,
            duration_s,
            seed: seed ^ 0x00f1_ee72,
            max_seq,
        },
        other => panic!("unknown fleet scenario {other:?}"),
    }
}

/// Stand up one fleet and drive it over the scenario's trace.
fn run_fleet(
    scenario: &str,
    router: &str,
    n: usize,
    soc: &SocConfig,
    duration_s: f64,
    seed: u64,
    energy_budget_j: f64,
) -> Result<FleetReport> {
    let geo = llama32_3b();
    let spec = scenario_spec(scenario, n, duration_s, seed, geo.max_seq);
    let inputs = fleet_user_flows(&spec, geo.vocab);
    let mut cfg = FleetConfig::new(n, router, geo, soc.clone());
    cfg.seed = seed;
    cfg.energy_budget_j = energy_budget_j;
    Fleet::new(cfg)?.run(inputs)
}

fn cell(v: f64) -> String {
    if v.is_finite() { format!("{v:.2}") } else { "-".into() }
}

fn fig_fleet_for(
    routers: &[&str],
    soc: &SocConfig,
    duration_s: f64,
    seed: u64,
    counts: &[usize],
) -> Result<Json> {
    let mut rows = vec![];
    let mut table = Table::new(&[
        "scenario", "router", "n", "makespan s", "rt p99 ttft ms", "pro tok/s",
        "cache hit", "energy imbal", "migr", "rej",
    ]);
    for &scenario in SCENARIOS {
        for &n in counts {
            // `route::names` lists sticky-session first; its run
            // calibrates the energy-budget cell (0 = unlimited when
            // the caller sweeps a sticky-less subset).
            let mut budget = 0.0;
            for &router in routers {
                let b = if router == "energy-budget" { budget } else { 0.0 };
                let rep = run_fleet(scenario, router, n, soc, duration_s, seed, b)?;
                if router == "sticky-session" {
                    let hottest = rep
                        .devices
                        .iter()
                        .map(|d| d.total_energy_j)
                        .fold(0.0, f64::max);
                    budget = ENERGY_BUDGET_FRAC * hottest;
                }
                table.row(vec![
                    scenario.to_string(),
                    router.to_string(),
                    n.to_string(),
                    cell(rep.makespan_us() / 1e6),
                    cell(rep.reactive_p99_ttft_ms()),
                    cell(rep.proactive_tokens_per_s()),
                    cell(rep.cache_hit_rate()),
                    cell(rep.energy_imbalance()),
                    rep.counters.migrations.to_string(),
                    rep.counters.rejections.to_string(),
                ]);
                rows.push(
                    rep.to_json()
                        .set("scenario", scenario)
                        .set("duration_s", duration_s)
                        .set("energy_budget_j", b),
                );
            }
        }
    }
    table.print();
    Ok(Json::obj().set("figure", "fleet").set("rows", Json::Arr(rows)))
}

/// `fig fleet [--smoke]` — every registered router across both
/// scenarios; device counts 4/16/64 (full) or 2/4 (smoke).
pub fn fig_fleet(soc: &SocConfig, duration_s: f64, seed: u64) -> Result<Json> {
    let counts = if duration_s < 15.0 { SMOKE_COUNTS } else { FULL_COUNTS };
    fig_fleet_for(route::names(), soc, duration_s, seed, counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;
    use crate::workload::Priority;

    fn mean_reactive_ttft_ms(rep: &FleetReport) -> f64 {
        let ttfts: Vec<f64> = rep
            .devices
            .iter()
            .flat_map(|d| d.reqs.iter())
            .filter(|m| m.priority == Priority::Reactive && !m.tool)
            .filter_map(|m| m.first_token_us.map(|t| (t - m.arrival_us) / 1e3))
            .collect();
        ttfts.iter().sum::<f64>() / ttfts.len() as f64
    }

    /// The headline affinity claim: on the uniform scenario sticky
    /// keeps every continuation warm while random pays cache-cold
    /// full-conversation prefills on ~(n-1)/n of them.
    #[test]
    fn sticky_beats_random_on_cache_hits_and_reactive_ttft() {
        let soc = default_soc();
        let sticky = run_fleet("uniform", "sticky-session", 4, &soc, 24.0, 7, 0.0).unwrap();
        let random = run_fleet("uniform", "random", 4, &soc, 24.0, 7, 0.0).unwrap();
        let (sh, rh) = (sticky.cache_hit_rate(), random.cache_hit_rate());
        assert!(sh > rh, "sticky hit-rate {sh} vs random {rh}");
        assert_eq!(sticky.counters.migrations, 0, "sticky never migrates unforced");
        assert!(random.counters.migrations > 0, "random must migrate");
        let (sp, rp) = (sticky.reactive_p99_ttft_ms(), random.reactive_p99_ttft_ms());
        assert!(sp.is_finite() && rp.is_finite());
        assert!(sp <= rp, "sticky p99 ttft {sp} ms vs random {rp} ms");
        let (sm, rm) = (mean_reactive_ttft_ms(&sticky), mean_reactive_ttft_ms(&random));
        assert!(sm < rm, "sticky mean ttft {sm} ms vs random {rm} ms");
    }

    /// The load-spreading claim: under skewed arrivals the hot user
    /// saturates sticky's one device, so least-loaded's migration
    /// prefills buy back far more queueing delay than they cost.
    #[test]
    fn least_loaded_no_worse_than_sticky_on_skewed_makespan() {
        let soc = default_soc();
        let sticky = run_fleet("skewed", "sticky-session", 4, &soc, 12.0, 11, 0.0).unwrap();
        let ll = run_fleet("skewed", "least-loaded", 4, &soc, 12.0, 11, 0.0).unwrap();
        assert!(
            ll.makespan_us() <= sticky.makespan_us(),
            "least-loaded makespan {} us vs sticky {} us",
            ll.makespan_us(),
            sticky.makespan_us()
        );
        assert!(ll.counters.migrations > 0, "spreading requires migrations");
    }

    /// The figure itself runs NaN-free strict JSON end-to-end for every
    /// registered router at smoke scale.
    #[test]
    fn fig_fleet_smoke_is_strict_json() {
        let j = fig_fleet_for(route::names(), &default_soc(), 8.0, 7, &[2]).unwrap();
        let text = j.to_string();
        assert!(!text.contains("NaN"), "invalid JSON token leaked: {text}");
        let back = Json::parse(&text).unwrap();
        let rows = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), SCENARIOS.len() * route::names().len());
    }
}
