//! `fig workflows` — agentic workflow DAGs (DESIGN.md §3): tool-call
//! nodes on the CPU, fan-out/join turns, and critical-path-aware
//! scheduling, quantified on every engine family.
//!
//! Two experiments:
//!
//! 1. **Mixed DAG workload** — reactive tool-agents, proactive
//!    map-reduce research flows, and proactive monitors with tool
//!    fetches, run on every engine family.  Reported per engine: DAG
//!    makespan vs the critical-path lower bound (their ratio is the
//!    scheduling-induced serialization of parallelizable branches),
//!    tool-node counts, prefix-cache hit-rate, and recomputed tokens.
//! 2. **Fan-out scenario** — one deep dependency chain contending with
//!    a stream of wide map-reduce flows; Agent.xpu with critical-path
//!    priority (`SchedulerConfig::critical_path_priority`) against the
//!    same engine in plain FIFO/ETC turn order.  Critical-path ordering
//!    keeps the deep chain's serial tail off the end of the schedule,
//!    so the overall DAG makespan strictly improves.
//! 3. **Deadline ablation** — the registry's `deadline` policy
//!    (slack-aware EDF, the first policy written against the
//!    `SchedPolicy` API) against plain FIFO/ETC reactive handling on a
//!    decode-contention workload: long proactive generations sharing
//!    the iGPU decode pipeline with a stream of reactive chats.  EDF's
//!    slack-gated batch formation keeps reactive decode batches lean
//!    once a deadline approaches, so reactive p99 latency drops.

use anyhow::Result;

use crate::config::{ModelGeometry, SchedulerConfig, SocConfig, llama32_3b};
use crate::coordinator::{AgentXpuEngine, DeadlineEngine};
use crate::engine::{EngineCore, registry};
use crate::metrics::{RunReport, percentile};
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::workload::{
    DagShape, DagSpec, Flow, FlowBinding, NodeKind, Priority, Request, dag_flow_trace,
    flatten_flows, merge_traces, profile,
};

fn geo_for_sweeps() -> ModelGeometry {
    llama32_3b()
}

fn num_or_null(v: f64) -> Json {
    Json::num_or_null(v)
}

/// Build the mixed workflow-DAG workload: reactive ReAct-style tool
/// agents + proactive map-reduce research flows + proactive monitors
/// whose wake-ups run a tool fetch before each digest.
pub fn dag_trace_mixed(duration_s: f64, seed: u64, geo: &ModelGeometry) -> Vec<Request> {
    let agents = dag_flow_trace(
        &DagSpec {
            profile: profile("lmsys").unwrap(),
            flow_rate_per_s: 0.05,
            think_time_s: 8.0,
            shape: DagShape::ToolAgent { rounds: 2 },
            duration_s,
            seed,
            max_seq: geo.max_seq,
        },
        Priority::Reactive,
        geo.vocab,
        0,
        0,
    );
    let mut next_id: u64 = agents.iter().map(|f| f.total_turns() as u64).sum();
    let mut next_flow = agents.len() as u64;
    let research = dag_flow_trace(
        &DagSpec {
            profile: profile("proactivebench").unwrap(),
            flow_rate_per_s: 0.04,
            think_time_s: 10.0,
            shape: DagShape::MapReduce { fanout: 3 },
            duration_s,
            seed: seed + 1,
            max_seq: geo.max_seq,
        },
        Priority::Proactive,
        geo.vocab,
        next_id,
        next_flow,
    );
    next_id += research.iter().map(|f| f.total_turns() as u64).sum::<u64>();
    next_flow += research.len() as u64;
    let monitors = dag_flow_trace(
        &DagSpec {
            profile: profile("samsum").unwrap(),
            flow_rate_per_s: 0.03,
            think_time_s: 15.0,
            shape: DagShape::MonitorTools { wakeups: 3 },
            duration_s,
            seed: seed + 2,
            max_seq: geo.max_seq,
        },
        Priority::Proactive,
        geo.vocab,
        next_id,
        next_flow,
    );
    let mut all = flatten_flows(agents);
    all.extend(flatten_flows(research));
    all.extend(flatten_flows(monitors));
    merge_traces(vec![all])
}

/// Hand-built deep dependency chain: `rounds` LLM turns, each a large
/// delta over the growing context, zero think-time — a serial tail
/// whose critical path dominates the workload.
fn deep_chain_flow(flow_id: u64, first_id: u64, arrival_us: f64, rounds: usize) -> Flow {
    let (p0, out, delta) = (256usize, 8usize, 160usize);
    let mut turns = vec![];
    let mut ctx = 0usize;
    for k in 0..rounds {
        let (plen, ds) = if k == 0 { (p0, 0) } else { (ctx + delta, ctx) };
        let mut prompt = vec![1i32; ds];
        prompt.extend(vec![3; plen - ds]);
        turns.push(Request {
            id: first_id + k as u64,
            priority: Priority::Proactive,
            arrival_us,
            prompt,
            max_new_tokens: out,
            profile: "deep-chain".into(),
            flow: Some(FlowBinding::linear(flow_id, k, rounds, 0.0, ds)),
        });
        ctx = plen + out;
    }
    Flow {
        id: flow_id,
        priority: Priority::Proactive,
        profile: "deep-chain".into(),
        turns,
    }
}

/// Hand-built wide map-reduce flow: a root digest fanning out `fanout`
/// small summarize branches joined by a synthesis turn — lots of
/// parallel slack, a short critical path.
fn wide_flow(flow_id: u64, first_id: u64, arrival_us: f64, fanout: usize) -> Flow {
    let (root_p, out, bdelta, jdelta) = (200usize, 8usize, 48usize, 32usize);
    let ctx0 = root_p + out;
    let mk = |idx: usize, plen: usize, ds: usize, deps: Vec<usize>| {
        let mut prompt = vec![1i32; ds];
        prompt.extend(vec![2; plen - ds]);
        Request {
            id: first_id + idx as u64,
            priority: Priority::Proactive,
            arrival_us,
            prompt,
            max_new_tokens: out,
            profile: "mapreduce".into(),
            flow: Some(FlowBinding {
                flow_id,
                turn_idx: idx,
                total_turns: fanout + 2,
                think_time_us: 0.0,
                delta_start: ds,
                deps,
                node: NodeKind::Llm,
                crit_path: 1, // annotated below
            }),
        }
    };
    let mut turns = vec![mk(0, root_p, 0, vec![])];
    for i in 0..fanout {
        turns.push(mk(1 + i, ctx0 + bdelta, ctx0, vec![0]));
    }
    let jds = (ctx0 + bdelta + out) + (fanout - 1) * (bdelta + out);
    turns.push(mk(fanout + 1, jds + jdelta, jds, (1..=fanout).collect()));
    let mut f = Flow {
        id: flow_id,
        priority: Priority::Proactive,
        profile: "mapreduce".into(),
        turns,
    };
    f.annotate_critical_paths();
    f
}

/// Decode-contention scenario for the `deadline` ablation: six long
/// proactive generations occupy the iGPU decode pipeline from t=0
/// while reactive chats arrive every 250 ms.  Under plain FIFO/ETC
/// handling every reactive decode iteration carries the proactive
/// lanes (bigger batch, larger average context → slower iterations for
/// the whole reactive tail); EDF's slack gate cuts the joins once a
/// reactive deadline approaches, so the reactive p99 improves.
pub fn edf_contention_trace() -> Vec<Request> {
    let mk = |id: u64, priority, arrival_us: f64, plen: usize, out: usize| Request {
        id,
        priority,
        arrival_us,
        prompt: vec![1; plen],
        max_new_tokens: out,
        profile: if priority == Priority::Reactive { "chat" } else { "digest" }.into(),
        flow: None,
    };
    let mut t: Vec<Request> = (0..6)
        .map(|i| mk(i, Priority::Proactive, 0.0, 256, 160))
        .collect();
    for i in 0..16u64 {
        t.push(mk(
            100 + i,
            Priority::Reactive,
            100_000.0 + i as f64 * 250_000.0,
            160,
            40,
        ));
    }
    t
}

/// The fan-out scenario: one 10-round deep chain at t=0 contending with
/// wide map-reduce flows arriving throughout its lifetime.  FIFO/ETC
/// turn order runs the short branch prefills first every round and
/// pushes the deep chain's serial tail to the end of the schedule;
/// critical-path priority resumes the deep chain first and lets the
/// wide flows fill the bubbles.
pub fn dag_fanout_trace() -> Vec<Request> {
    let mut flows = vec![deep_chain_flow(1, 0, 0.0, 10)];
    for i in 0..8u64 {
        flows.push(wide_flow(
            2 + i,
            1_000 + 100 * i,
            200_000.0 + i as f64 * 400_000.0,
            4,
        ));
    }
    flatten_flows(flows)
}

fn row_from(rep: &RunReport) -> (usize, usize, usize, f64, f64, f64, usize) {
    let flows = rep.flows();
    let unfinished = rep.reqs.iter().filter(|m| !m.finished()).count();
    let tools = flows.iter().map(|f| f.tool_turns).sum();
    let mk = rep.mean_flow_makespan_ms();
    let cp = rep.mean_flow_critical_path_ms();
    (
        flows.len(),
        unfinished,
        tools,
        mk,
        cp,
        rep.prefix_cache_hit_rate(),
        rep.recomputed_prefill_tokens(),
    )
}

/// The `fig workflows` harness (see module docs).
pub fn fig_workflows(soc: &SocConfig, duration_s: f64, seed: u64) -> Result<Json> {
    let geo = geo_for_sweeps();
    let trace = dag_trace_mixed(duration_s, seed, &geo);
    let mut rows = vec![];
    let mut table = Table::new(&[
        "engine", "flows", "tools", "DAG makespan (ms)", "crit-path (ms)",
        "cp-efficiency", "hit-rate", "recomputed tok",
    ]);
    // Engine families by registry name — the `deadline` policy ablates
    // alongside the pre-existing four automatically.
    let mut engines: Vec<Box<dyn EngineCore + Send>> =
        ["agent-xpu", "scheme-a", "scheme-c", "cpu-fcfs", "deadline"]
            .iter()
            .map(|n| {
                registry::build(n, geo.clone(), soc.clone(), SchedulerConfig::default())
            })
            .collect::<Result<_>>()?;
    for e in engines.iter_mut() {
        let rep = e.run(trace.clone())?;
        let (nflows, unfinished, tools, mk, cp, hit, recomputed) = row_from(&rep);
        let eff = if mk > 0.0 { cp / mk } else { f64::NAN };
        table.row(vec![
            rep.engine.clone(),
            format!("{nflows}"),
            format!("{tools}"),
            format!("{mk:.1}"),
            format!("{cp:.1}"),
            format!("{eff:.2}"),
            format!("{hit:.2}"),
            format!("{recomputed}"),
        ]);
        rows.push(
            Json::obj()
                .set("engine", rep.engine.as_str())
                .set("flows", nflows)
                .set("unfinished", unfinished)
                .set("tool_turns", tools)
                .set("mean_flow_makespan_ms", num_or_null(mk))
                .set("mean_critical_path_ms", num_or_null(cp))
                .set("cp_efficiency", num_or_null(eff))
                .set("prefix_cache_hit_rate", num_or_null(hit))
                .set("recomputed_prefill_tokens", recomputed),
        );
    }
    println!("\n== fig-workflows: workflow DAGs across engine families ==");
    println!("(cp-efficiency = critical-path lower bound / DAG makespan; 1.0 = no");
    println!(" scheduling-induced serialization of parallelizable branches)");
    table.print();

    // Fan-out head-to-head: critical-path priority vs FIFO/ETC order.
    let fanout = dag_fanout_trace();
    let mut cp_engine = AgentXpuEngine::synthetic(
        geo.clone(),
        soc.clone(),
        SchedulerConfig::default(),
    );
    let rep_cp = cp_engine.run(fanout.clone())?;
    let mut fifo_engine = AgentXpuEngine::synthetic(
        geo,
        soc.clone(),
        SchedulerConfig { critical_path_priority: false, ..Default::default() },
    );
    let rep_fifo = fifo_engine.run(fanout)?;
    println!(
        "\nfan-out scenario (1 deep chain + 8 wide map-reduce flows):\n\
         critical-path order: makespan {:.1} ms, mean flow e2e {:.1} ms\n\
         fifo/etc turn order: makespan {:.1} ms, mean flow e2e {:.1} ms",
        rep_cp.makespan_us / 1e3,
        rep_cp.mean_flow_e2e_ms(),
        rep_fifo.makespan_us / 1e3,
        rep_fifo.mean_flow_e2e_ms(),
    );
    let fanout_json = Json::obj()
        .set("cp_makespan_ms", rep_cp.makespan_us / 1e3)
        .set("fifo_makespan_ms", rep_fifo.makespan_us / 1e3)
        .set("cp_mean_flow_e2e_ms", num_or_null(rep_cp.mean_flow_e2e_ms()))
        .set("fifo_mean_flow_e2e_ms", num_or_null(rep_fifo.mean_flow_e2e_ms()));

    // Deadline ablation: slack-aware EDF vs the default agent-xpu
    // ordering (FCFS admission + ETC-ranked resumption + always-join
    // batching — the "plain FIFO/ETC" axis; the trace has no flows, so
    // critical-path priority is inert and the default config is the
    // honest baseline) on the decode-contention scenario.
    let contention = edf_contention_trace();
    let mut edf = DeadlineEngine::synthetic(
        geo_for_sweeps(),
        soc.clone(),
        SchedulerConfig::default(),
    );
    let rep_edf = edf.run(contention.clone())?;
    let mut fifo = AgentXpuEngine::synthetic(
        geo_for_sweeps(),
        soc.clone(),
        SchedulerConfig::default(),
    );
    let rep_plain = fifo.run(contention)?;
    let p99 = |rep: &RunReport| {
        let mut e2e: Vec<f64> = rep
            .reqs
            .iter()
            .filter(|m| m.priority == Priority::Reactive)
            .filter_map(|m| m.e2e_us())
            .collect();
        e2e.sort_by(|a, b| a.total_cmp(b));
        percentile(&e2e, 0.99) / 1e3
    };
    let (edf_p99, plain_p99) = (p99(&rep_edf), p99(&rep_plain));
    println!(
        "\ndeadline ablation (6 long proactive decodes + reactive stream):\n\
         deadline (EDF):          reactive p99 e2e {edf_p99:.1} ms\n\
         agent-xpu fifo/etc:      reactive p99 e2e {plain_p99:.1} ms",
    );
    let deadline_json = Json::obj()
        .set("edf_reactive_p99_ms", num_or_null(edf_p99))
        .set("fifo_reactive_p99_ms", num_or_null(plain_p99));

    Ok(Json::obj()
        .set("figure", "workflows")
        .set("rows", Json::Arr(rows))
        .set("fanout", fanout_json)
        .set("deadline", deadline_json))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;

    #[test]
    fn dag_trace_mixed_has_all_shapes_and_unique_ids() {
        let geo = llama32_3b();
        let t = dag_trace_mixed(120.0, 7, &geo);
        assert!(t.iter().any(|r| r.priority == Priority::Reactive && r.flow.is_some()));
        assert!(t.iter().any(|r| r.priority == Priority::Proactive && r.flow.is_some()));
        assert!(t.iter().any(|r| r.is_tool()), "tool nodes present");
        let mut ids: Vec<u64> = t.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), t.len(), "request ids unique across DAG streams");
        let mut fids: Vec<(u64, usize)> = t
            .iter()
            .filter_map(|r| r.flow.as_ref().map(|f| (f.flow_id, f.turn_idx)))
            .collect();
        fids.sort_unstable();
        fids.dedup();
        assert_eq!(fids.len(), t.len(), "(flow, node) pairs unique");
    }

    #[test]
    fn fig_workflows_completes_everywhere_and_cp_beats_fifo() {
        let j = fig_workflows(&default_soc(), 90.0, 7).unwrap();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert!(rows.len() >= 5, "all engine families (incl. deadline) ran");
        assert!(
            rows.iter().any(|r| {
                r.get("engine").unwrap().as_str().unwrap() == "deadline"
            }),
            "the registry's deadline policy is ablated alongside the rest"
        );
        for r in rows {
            // acceptance: every engine family drains the DAG workload
            assert_eq!(
                r.get("unfinished").unwrap().as_usize().unwrap(),
                0,
                "{} lost workflow nodes",
                r.get("engine").unwrap().as_str().unwrap()
            );
            assert!(r.get("tool_turns").unwrap().as_usize().unwrap() > 0);
            // makespan is bounded below by the critical path
            let mk = r.get("mean_flow_makespan_ms").unwrap().as_f64().unwrap();
            let cp = r.get("mean_critical_path_ms").unwrap().as_f64().unwrap();
            assert!(mk + 1e-6 >= cp, "makespan {mk} below critical path {cp}");
        }
        // acceptance: critical-path-aware ordering strictly improves the
        // DAG makespan over FIFO turn order on the fan-out scenario
        let f = j.get("fanout").unwrap();
        let cp = f.get("cp_makespan_ms").unwrap().as_f64().unwrap();
        let fifo = f.get("fifo_makespan_ms").unwrap().as_f64().unwrap();
        assert!(
            cp < fifo,
            "critical-path order must strictly beat FIFO: {cp} vs {fifo}"
        );
        // acceptance: the deadline policy's slack-aware EDF beats plain
        // FIFO/ETC reactive p99 latency on the contention scenario
        let d = j.get("deadline").unwrap();
        let edf = d.get("edf_reactive_p99_ms").unwrap().as_f64().unwrap();
        let plain = d.get("fifo_reactive_p99_ms").unwrap().as_f64().unwrap();
        assert!(
            edf < plain,
            "deadline EDF must beat plain FIFO/ETC reactive p99: {edf} vs {plain}"
        );
    }
}
