//! Overload figure (DESIGN.md §7): graceful degradation under
//! admission control + priority-aware shedding versus the cliff-edge
//! un-governed baseline.
//!
//! For every registry policy the harness first *calibrates*: a
//! light-load run measures the policy's own reactive p99 TTFT, and the
//! SLO is set to a multiple of that (clamped, so a baseline whose
//! light-load tail is already seconds long cannot award itself an
//! unfalsifiable budget).  It then ramps the proactive arrival rate
//! past capacity and serves each point twice over the identical trace:
//!
//! - **governed** — through [`run_governed`]: bounded queue,
//!   reactive-displaces-proactive admission, and the policy's
//!   [`SchedPolicy::shed_level`] escalation
//!   (pause → cancel queued → park running);
//! - **un-governed** — the plain `EngineCore::run` batch driver, every
//!   arrival admitted, nothing shed.
//!
//! Reactive p99 TTFT is measured over the steady-state tail (arrivals
//! after the warmup fraction): the governor needs a few detector
//! passes to engage, and serving benchmarks exclude ramp-up for the
//! same reason.  The acceptance claim: at the deepest overload the
//! governed engine keeps reactive p99 within the SLO multiple while
//! proactive throughput degrades first; the un-governed run blows
//! through it.
//!
//! [`SchedPolicy::shed_level`]: crate::engine::SchedPolicy::shed_level

use anyhow::Result;

use crate::config::{OverloadConfig, SchedulerConfig, SocConfig, llama32_3b};
use crate::engine::{EngineCore, registry};
use crate::metrics::{RunReport, percentile};
use crate::server::{GovernedOutcome, run_governed};
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::workload::Priority;

use super::mixed_trace;

/// Proactive arrivals/s at ramp multiplier 1.
const BASE_PROACTIVE_RATE: f64 = 1.0;

/// Reactive arrival spacing (s): dense enough for a meaningful p99.
const REACTIVE_INTERVAL_S: f64 = 0.5;

/// Fraction of the trace treated as warmup when measuring p99: the
/// detector needs queue depth or a finished slow reactive turn before
/// it can escalate, so arrivals during ramp-up see pre-governance
/// collisions in every policy without a preemptive scheduler.
const WARMUP_FRAC: f64 = 0.5;

/// SLO calibration: `clamp(CAL_MULT × light-load p99, floor, ceil)`.
const CAL_MULT: f64 = 4.0;
const SLO_FLOOR_MS: f64 = 50.0;
const SLO_CEIL_MS: f64 = 1000.0;

/// Governed queue bound for the ramp.
const QUEUE_DEPTH: usize = 32;

/// Reactive p99 TTFT (ms) over finished reactive arrivals at or after
/// `from_us`; NaN when no such request finished.
fn reactive_p99_ttft_ms(rep: &RunReport, from_us: f64) -> f64 {
    let mut ttfts: Vec<f64> = rep
        .reqs
        .iter()
        .filter(|r| r.priority == Priority::Reactive && r.arrival_us >= from_us)
        .filter_map(|r| r.first_token_us.map(|ft| (ft - r.arrival_us) / 1e3))
        .collect();
    if ttfts.is_empty() {
        return f64::NAN;
    }
    ttfts.sort_by(f64::total_cmp);
    percentile(&ttfts, 0.99)
}

fn overload_row(
    policy: &str,
    mult: f64,
    governed: bool,
    rep: &RunReport,
    p99_ms: f64,
    slo_ms: f64,
    threshold_ms: f64,
    out: Option<&GovernedOutcome>,
) -> Json {
    let pro = rep.class(Priority::Proactive);
    Json::obj()
        .set("policy", policy)
        .set("engine", rep.engine.as_str())
        .set("mult", mult)
        .set("proactive_rate_per_s", BASE_PROACTIVE_RATE * mult)
        .set("governed", governed)
        .set("reactive_p99_ttft_ms", Json::num_or_null(p99_ms))
        .set("slo_ms", slo_ms)
        .set("threshold_ms", threshold_ms)
        .set("within_slo_multiple", p99_ms.is_finite() && p99_ms <= threshold_ms)
        .set("proactive_tok_s", pro.tokens_per_s)
        .set("rejected_reactive", out.map(|o| o.rejected_reactive).unwrap_or(0))
        .set("rejected_proactive", out.map(|o| o.rejected_proactive).unwrap_or(0))
        .set("displaced", out.map(|o| o.displaced).unwrap_or(0))
        .set("shed", out.map(|o| o.shed).unwrap_or(0))
        .set("parked", out.map(|o| o.parked).unwrap_or(0))
}

fn fig_overload_for(
    policies: &[&str],
    soc: &SocConfig,
    duration_s: f64,
    seed: u64,
    mults: &[f64],
) -> Result<Json> {
    let geo = llama32_3b();
    let warmup_us = WARMUP_FRAC * duration_s * 1e6;
    let mut rows = vec![];
    let mut table = Table::new(&[
        "policy", "mult", "mode", "rt p99 ttft ms", "slo×4 ms", "pro tok/s",
        "rej", "shed", "parked",
    ]);
    for policy in policies {
        // Calibration: the policy's own light-load reactive tail sets
        // its SLO (clamped: a sloppy baseline cannot self-award an
        // unfalsifiable budget, and a tight one keeps a testable floor)
        let light = mixed_trace(0.25, 1.0, duration_s, seed, &geo);
        let light_rep = registry::build(
            policy,
            geo.clone(),
            soc.clone(),
            SchedulerConfig::default(),
        )?
        .run(light)?;
        let light_p99 = reactive_p99_ttft_ms(&light_rep, warmup_us);
        let slo_ms = if light_p99.is_finite() {
            (CAL_MULT * light_p99).clamp(SLO_FLOOR_MS, SLO_CEIL_MS)
        } else {
            SLO_CEIL_MS
        };
        let cfg = OverloadConfig {
            max_queue_depth: QUEUE_DEPTH,
            max_live_flows: 0,
            reactive_ttft_slo_ms: slo_ms,
            slo_multiple: 4.0,
            retry_after_ms: 250.0,
            fsync_every: 1,
        };
        let threshold_ms = slo_ms * cfg.slo_multiple;
        for &mult in mults {
            let trace = mixed_trace(
                BASE_PROACTIVE_RATE * mult,
                REACTIVE_INTERVAL_S,
                duration_s,
                seed.wrapping_add(mult as u64),
                &geo,
            );
            // un-governed: every arrival admitted, nothing shed
            let rep_un = registry::build(
                policy,
                geo.clone(),
                soc.clone(),
                SchedulerConfig::default(),
            )?
            .run(trace.clone())?;
            let p99_un = reactive_p99_ttft_ms(&rep_un, warmup_us);
            rows.push(overload_row(
                policy, mult, false, &rep_un, p99_un, slo_ms, threshold_ms, None,
            ));
            table.row(vec![
                (*policy).into(),
                format!("{mult:.0}x"),
                "raw".into(),
                format!("{p99_un:.1}"),
                format!("{threshold_ms:.0}"),
                format!("{:.1}", rep_un.class(Priority::Proactive).tokens_per_s),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            // governed: same trace through the admission gate + the
            // policy's shed-level escalation
            let mut eng = registry::build(
                policy,
                geo.clone(),
                soc.clone(),
                SchedulerConfig::default(),
            )?;
            let out = run_governed(eng.as_mut(), trace, &cfg)?;
            let p99_gov = reactive_p99_ttft_ms(&out.report, warmup_us);
            table.row(vec![
                (*policy).into(),
                format!("{mult:.0}x"),
                "gov".into(),
                format!("{p99_gov:.1}"),
                format!("{threshold_ms:.0}"),
                format!("{:.1}", out.report.class(Priority::Proactive).tokens_per_s),
                format!("{}", out.rejected_reactive + out.rejected_proactive),
                format!("{}", out.shed),
                format!("{}", out.parked),
            ]);
            let rep = out.report.clone();
            rows.push(overload_row(
                policy,
                mult,
                true,
                &rep,
                p99_gov,
                slo_ms,
                threshold_ms,
                Some(&out),
            ));
        }
    }
    println!("\n== fig-overload: admission control & load shedding (DESIGN.md §7) ==");
    println!(
        "(ramp past saturation; gov = bounded queue + priority shedding, raw = admit all)"
    );
    table.print();
    Ok(Json::obj().set("figure", "overload").set("rows", Json::Arr(rows)))
}

/// The overload ramp over every registry policy.  Short durations
/// (`--smoke`) use a two-point ramp; full runs sweep five multipliers.
pub fn fig_overload(soc: &SocConfig, duration_s: f64, seed: u64) -> Result<Json> {
    let mults: &[f64] = if duration_s < 15.0 {
        &[1.0, 8.0]
    } else {
        &[1.0, 2.0, 4.0, 8.0, 16.0]
    };
    fig_overload_for(registry::names(), soc, duration_s, seed, mults)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;

    /// The acceptance criterion end-to-end on the cliff-edge baseline:
    /// at the deepest overload point the governed cpu-fcfs engine keeps
    /// reactive p99 TTFT within the calibrated SLO multiple (shedding
    /// proactive work to do it) while the un-governed run blows past
    /// it; the governed agent-xpu engine stays within budget too.  The
    /// JSON must be NaN-free and parse back.
    #[test]
    fn governed_ramp_degrades_gracefully_where_ungoverned_cliffs() {
        let j =
            fig_overload_for(&["cpu-fcfs", "agent-xpu"], &default_soc(), 10.0, 7, &[8.0])
                .unwrap();
        let text = j.to_string();
        assert!(!text.contains("NaN"), "invalid JSON token leaked: {text}");
        let back = Json::parse(&text).expect("figure output must parse");
        let rows = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 4, "2 policies x 1 mult x (raw, gov)");
        let row = |policy: &str, governed: bool| -> &Json {
            rows.iter()
                .find(|r| {
                    r.get("policy").unwrap().as_str().unwrap() == policy
                        && r.get("governed").unwrap().as_bool().unwrap() == governed
                })
                .unwrap_or_else(|| panic!("row {policy}/governed={governed}"))
        };
        let p99 = |policy: &str, governed: bool| -> f64 {
            row(policy, governed)
                .get("reactive_p99_ttft_ms")
                .unwrap()
                .as_f64()
                .expect("steady-state reactive requests must finish")
        };
        let threshold = row("cpu-fcfs", false)
            .get("threshold_ms")
            .unwrap()
            .as_f64()
            .unwrap();
        // the un-governed FCFS baseline cliffs: reactive arrivals sit
        // behind an unbounded proactive backlog
        assert!(
            p99("cpu-fcfs", false) > threshold,
            "un-governed cpu-fcfs must blow past {threshold}ms, got {}",
            p99("cpu-fcfs", false)
        );
        // governed, the same policy sheds proactive work first and the
        // reactive tail stays within the SLO multiple
        assert!(
            p99("cpu-fcfs", true) <= threshold,
            "governed cpu-fcfs must stay within {threshold}ms, got {}",
            p99("cpu-fcfs", true)
        );
        let gov = row("cpu-fcfs", true);
        let shed_total = gov.get("shed").unwrap().as_usize().unwrap()
            + gov.get("parked").unwrap().as_usize().unwrap()
            + gov.get("rejected_proactive").unwrap().as_usize().unwrap()
            + gov.get("displaced").unwrap().as_usize().unwrap();
        assert!(shed_total > 0, "graceful degradation requires actual shedding");
        // proactive throughput is what degrades: governed serves fewer
        // proactive tokens than the un-governed run at the same load
        let pro = |governed: bool| {
            row("cpu-fcfs", governed).get("proactive_tok_s").unwrap().as_f64().unwrap()
        };
        assert!(pro(true) <= pro(false), "proactive throughput must degrade first");
        // governance holds for the preemptive flagship engine too
        let agent_threshold = row("agent-xpu", true)
            .get("threshold_ms")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(
            p99("agent-xpu", true) <= agent_threshold,
            "governed agent-xpu must stay within {agent_threshold}ms, got {}",
            p99("agent-xpu", true)
        );
    }
}
