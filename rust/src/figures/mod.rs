//! Figure/table regenerators — one per figure of the paper's analysis
//! (§3) and evaluation (§8) sections, plus the ablation study DESIGN.md
//! calls for.  Each returns machine-readable JSON (written under
//! `results/` by the CLI) and prints the same rows/series the paper
//! plots.  See DESIGN.md §4 for the experiment index and EXPERIMENTS.md
//! for recorded paper-vs-measured comparisons.

mod e2e;
mod elastic;
mod energy;
mod fleet;
mod micro;
mod overload;
mod workflows;

pub use e2e::{
    fig_ablation, fig_flows, fig_mixed, fig_proactive, fig_schemes, flow_trace_mixed,
    mixed_trace,
};
pub use elastic::fig_elastic;
pub use energy::fig_energy;
pub use fleet::fig_fleet;
pub use micro::{fig_affinity, fig_batching, fig_contention};
pub use overload::fig_overload;
pub use workflows::{
    dag_fanout_trace, dag_trace_mixed, edf_contention_trace, fig_workflows,
};
