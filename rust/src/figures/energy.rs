//! Energy & graphics-interference figure — the paper's closing claim
//! (§8.1): "Agent.xpu also minimizes energy consumption and graphics
//! interference via controlled iGPU usage".
//!
//! A 60 Hz display workload renders on the iGPU while every engine
//! family serves the same proactive-dominant agentic mix; the agent-xpu
//! duty-governor knobs (`igpu_duty_cap`, `yield_to_graphics`) sweep
//! against the ungoverned baselines.  Reported per run: per-class
//! energy attribution and J/token, frame-deadline (jank) statistics,
//! and the agentic throughput the governor trades away.
//!
//! The baselines never place proactive work through the coordinator's
//! iGPU gates, so the duty knobs are inert for them — the sweep shows
//! that invariance explicitly instead of assuming it.

use anyhow::Result;

use crate::config::{SchedulerConfig, SocConfig, llama32_3b};
use crate::engine::{EngineCore, registry};
use crate::metrics::RunReport;
use crate::soc::{CLASS_IDLE, GraphicsConfig, KernelClass};
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::workload::Priority;

use super::mixed_trace;

/// One governor setting of the sweep.
const VARIANTS: [(&str, f64, bool); 3] =
    [("uncapped", 1.0, false), ("cap-0.5", 0.5, false), ("cap-0.3", 0.3, false)];

/// Engine families crossed with the duty-cap variants.
const FAMILIES: [&str; 3] = ["agent-xpu", "scheme-c", "cpu-fcfs"];

fn energy_row(
    rep: &RunReport,
    family: &str,
    variant: &str,
    duty_cap: f64,
    yield_g: bool,
) -> Json {
    let r = rep.class(Priority::Reactive);
    let p = rep.class(Priority::Proactive);
    Json::obj()
        .set("engine", rep.engine.as_str())
        .set("family", family)
        .set("variant", variant)
        .set("igpu_duty_cap", duty_cap)
        .set("yield_to_graphics", yield_g)
        .set("frames_scheduled", rep.frames_scheduled as usize)
        .set("frames_missed", rep.frames_missed as usize)
        .set("frame_miss_rate", rep.frame_miss_rate())
        .set("joules_per_token", rep.joules_per_token())
        .set(
            "reactive_j_per_token",
            rep.joules_per_token_class(Priority::Reactive),
        )
        .set(
            "proactive_j_per_token",
            rep.joules_per_token_class(Priority::Proactive),
        )
        .set("reactive_energy_j", rep.energy_by_class[KernelClass::Reactive.idx()])
        .set("proactive_energy_j", rep.energy_by_class[KernelClass::Proactive.idx()])
        .set("graphics_energy_j", rep.energy_by_class[KernelClass::Graphics.idx()])
        .set("idle_energy_j", rep.energy_by_class[CLASS_IDLE])
        .set("total_energy_j", rep.total_energy_j)
        .set("reactive_mean_ttft_ms", Json::num_or_null(r.mean_ttft_ms))
        .set("proactive_tok_s", p.tokens_per_s)
        .set("makespan_s", rep.makespan_us / 1e6)
        .set("backfills", rep.backfills as usize)
}

/// The energy/interference sweep: duty-cap variants × engine families,
/// all serving the same seeded proactive-dominant trace against the
/// same 60 Hz display workload.
pub fn fig_energy(soc: &SocConfig, duration_s: f64, seed: u64) -> Result<Json> {
    let geo = llama32_3b();
    // proactive-dominant: background decode is what squats on the iGPU
    // across vsync; one sparse reactive stream keeps the preemption
    // path honest
    let trace = mixed_trace(0.5, duration_s.max(20.0), duration_s, seed, &geo);
    let gfx = GraphicsConfig::default();

    let mut rows = vec![];
    let mut table = Table::new(&[
        "engine", "variant", "frames", "missed", "miss-rate",
        "pro J/tok", "rt J/tok", "gfx J", "idle J", "pro tok/s",
    ]);
    for family in FAMILIES {
        for (variant, cap, yield_g) in VARIANTS {
            let mut sched = SchedulerConfig::default();
            sched.igpu_duty_cap = cap;
            sched.yield_to_graphics = yield_g;
            let mut e = registry::build(family, geo.clone(), soc.clone(), sched)?;
            e.set_graphics(Some(gfx.clone()));
            let rep = e.run(trace.clone())?;
            table.row(vec![
                rep.engine.clone(),
                variant.into(),
                format!("{}", rep.frames_scheduled),
                format!("{}", rep.frames_missed),
                format!("{:.3}", rep.frame_miss_rate()),
                format!("{:.2}", rep.joules_per_token_class(Priority::Proactive)),
                format!("{:.2}", rep.joules_per_token_class(Priority::Reactive)),
                format!("{:.1}", rep.energy_by_class[KernelClass::Graphics.idx()]),
                format!("{:.1}", rep.energy_by_class[CLASS_IDLE]),
                format!("{:.1}", rep.class(Priority::Proactive).tokens_per_s),
            ]);
            rows.push(energy_row(&rep, family, variant, cap, yield_g));
        }
    }
    // the extreme point: hard yield to every vsync on top of the cap
    {
        let mut sched = SchedulerConfig::default();
        sched.igpu_duty_cap = 0.3;
        sched.yield_to_graphics = true;
        let mut e = registry::build("agent-xpu", geo.clone(), soc.clone(), sched)?;
        e.set_graphics(Some(gfx.clone()));
        let rep = e.run(trace.clone())?;
        rows.push(energy_row(&rep, "agent-xpu", "cap-0.3+yield", 0.3, true));
    }
    println!("\n== fig-energy: energy & graphics interference (§8.1) ==");
    println!(
        "(60 Hz display on the iGPU; miss-rate = frames past their vsync deadline)"
    );
    table.print();
    Ok(Json::obj().set("figure", "energy").set("rows", Json::Arr(rows)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;

    /// The acceptance criterion end-to-end: parseable NaN-free JSON
    /// with per-class J/token + frame_miss_rate, the engaged duty cap
    /// strictly reducing agent-xpu's jank vs the uncapped run, and the
    /// knobs inert for baselines that never consult the governor.
    #[test]
    fn energy_figure_smoke_is_parseable_and_cap_reduces_jank() {
        let j = fig_energy(&default_soc(), 15.0, 7).unwrap();
        let text = j.to_string();
        assert!(!text.contains("NaN"), "invalid JSON token leaked: {text}");
        let back = Json::parse(&text).expect("figure output must parse");
        let rows = back.get("rows").unwrap().as_arr().unwrap();
        assert!(rows.len() >= FAMILIES.len() * VARIANTS.len());
        let get = |family: &str, variant: &str, k: &str| -> f64 {
            rows.iter()
                .find(|r| {
                    r.get("family").unwrap().as_str().unwrap() == family
                        && r.get("variant").unwrap().as_str().unwrap() == variant
                })
                .unwrap_or_else(|| panic!("row {family}/{variant}"))
                .get(k)
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // per-class energy fields are present and defined on every row
        for r in rows {
            assert!(r.get("proactive_j_per_token").unwrap().as_f64().unwrap() >= 0.0);
            assert!(r.get("frame_miss_rate").unwrap().as_f64().unwrap() >= 0.0);
        }
        // the ungoverned agent engine janks the display...
        assert!(get("agent-xpu", "uncapped", "frames_missed") > 0.0);
        // ...and the engaged cap strictly reduces the miss rate
        assert!(
            get("agent-xpu", "cap-0.3", "frame_miss_rate")
                < get("agent-xpu", "uncapped", "frame_miss_rate"),
            "duty cap must strictly reduce jank"
        );
        // baselines never consult the governor: the knobs are inert
        for k in ["frame_miss_rate", "proactive_tok_s"] {
            assert_eq!(
                get("cpu-fcfs", "uncapped", k),
                get("cpu-fcfs", "cap-0.3", k),
                "cpu-fcfs must ignore the duty knobs ({k})"
            );
        }
        // the CPU baseline leaves the iGPU to the display: ~no jank
        assert!(
            get("cpu-fcfs", "uncapped", "frame_miss_rate")
                <= get("agent-xpu", "uncapped", "frame_miss_rate")
        );
    }
}
