//! Integration: the Rust runtime must reproduce the Python golden
//! trajectories bit-for-bit (greedy decoding ⇒ exact token match).

use agent_xpu::runtime::{ModelExecutor, Runtime};
use std::sync::Arc;

struct GoldenCase {
    prompt: Vec<i32>,
    chunk: usize,
    generated: Vec<i32>,
}

fn load_golden(path: &std::path::Path) -> Vec<GoldenCase> {
    let v = agent_xpu::util::json::Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    v.as_arr()
        .unwrap()
        .iter()
        .map(|c| GoldenCase {
            prompt: c.get("prompt").unwrap().as_i32_vec().unwrap(),
            chunk: c.get("chunk").unwrap().as_usize().unwrap(),
            generated: c.get("generated").unwrap().as_i32_vec().unwrap(),
        })
        .collect()
}

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn rust_runtime_matches_python_golden() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Arc::new(Runtime::load(&dir).expect("load runtime"));
    let cases = load_golden(&dir.join("golden.json"));
    assert!(!cases.is_empty());
    let exec = ModelExecutor::new(rt);
    for (i, case) in cases.iter().enumerate() {
        let got = exec
            .generate(&case.prompt, case.chunk, case.generated.len())
            .expect("generate");
        assert_eq!(got, case.generated, "golden case {i} diverged");
    }
}

#[test]
fn chunk_choice_does_not_change_tokens() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Arc::new(Runtime::load(&dir).expect("load runtime"));
    let exec = ModelExecutor::new(rt.clone());
    let prompt: Vec<i32> = (0..23).map(|i| (i * 37) % rt.geo.vocab as i32).collect();
    let mut outs = vec![];
    for &chunk in &rt.geo.chunk_sizes {
        outs.push(exec.generate(&prompt, chunk, 5).unwrap());
    }
    assert!(outs.windows(2).all(|w| w[0] == w[1]));
}
