//! Property tests over the fleet layer (DESIGN.md §9): every
//! registered router, on seeded multi-user traces, upholds the fleet
//! invariants:
//!
//!  - determinism: identical (trace, seed, router) → bit-identical
//!    schedules across every device;
//!  - energy rollup: the per-device `total_energy_j` values serialized
//!    into the report sum to the fleet rollup;
//!  - conservation: per device `submitted == done + cancelled`, and
//!    every flow ends finished or dead-with-shed-accounting — even
//!    under a deliberately tiny admission gate that forces the
//!    rejection → re-route → park → retry path ([`RouteError`]).
//!
//! [`RouteError`]: agent_xpu::fleet::RouteError

use agent_xpu::config::{default_soc, llama32_3b};
use agent_xpu::fleet::{Fleet, FleetConfig, FleetReport, route};
use agent_xpu::util::json::Json;
use agent_xpu::workload::{FleetSpec, UserFlow, fleet_user_flows};

/// A small mixed-class multi-user trace (reactive chats + proactive
/// monitors across `users` zipf-weighted users).
fn trace(users: usize, duration_s: f64, seed: u64) -> Vec<UserFlow> {
    let geo = llama32_3b();
    fleet_user_flows(
        &FleetSpec {
            users,
            zipf_exponent: 0.8,
            chat_rate_per_s: 0.15,
            monitor_rate_per_s: 0.08,
            duration_s,
            seed,
            max_seq: geo.max_seq,
        },
        geo.vocab,
    )
}

fn run(router: &str, n_devices: usize, inputs: Vec<UserFlow>, seed: u64) -> FleetReport {
    let mut cfg = FleetConfig::new(n_devices, router, llama32_3b(), default_soc());
    cfg.seed = seed;
    Fleet::new(cfg).unwrap().run(inputs).unwrap()
}

/// FNV-style fingerprint of everything schedule-shaped in a fleet
/// report: per-device request lifecycles at full f64 precision plus
/// the routing counters.  Equal fingerprints ⇒ identical schedules.
fn fingerprint(rep: &FleetReport) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for (di, d) in rep.devices.iter().enumerate() {
        mix(di as u64);
        mix(d.reqs.len() as u64);
        for m in &d.reqs {
            mix(m.id);
            mix(m.arrival_us.to_bits());
            mix(m.first_token_us.map_or(0, f64::to_bits));
            mix(m.done_us.map_or(0, f64::to_bits));
            mix(m.output_tokens as u64);
        }
        mix(d.total_energy_j.to_bits());
    }
    let c = &rep.counters;
    for v in [
        c.flows,
        c.flows_finished,
        c.flows_dead,
        c.migrations,
        c.overload_reroutes,
        c.rejections,
        c.retries,
        c.displaced,
        c.shed_turns,
        c.continuation_turns,
        c.continuation_warm,
    ] {
        mix(v);
    }
    h
}

#[test]
fn every_router_is_seed_deterministic() {
    for &router in route::names() {
        let a = run(router, 3, trace(5, 8.0, 21), 21);
        let b = run(router, 3, trace(5, 8.0, 21), 21);
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "router {router} must be deterministic under a fixed seed"
        );
        let c = run(router, 3, trace(5, 8.0, 22), 22);
        assert!(
            fingerprint(&a) != fingerprint(&c) || a.finished() == 0,
            "router {router}: a different seed should change the schedule"
        );
    }
}

#[test]
fn device_energy_sums_to_fleet_rollup() {
    for &router in route::names() {
        let rep = run(router, 3, trace(5, 8.0, 33), 33);
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        let total = j.get("total_energy_j").unwrap().as_f64().unwrap();
        let sum: f64 = j
            .get("devices")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.get("total_energy_j").unwrap().as_f64().unwrap())
            .sum();
        assert!(
            (total - sum).abs() <= 1e-9 * total.max(1.0),
            "router {router}: rollup {total} J != device sum {sum} J"
        );
        assert!(total > 0.0, "router {router}: a served trace burns energy");
    }
}

#[test]
fn conservation_holds_per_device_and_per_flow() {
    for &router in route::names() {
        let rep = run(router, 3, trace(5, 8.0, 44), 44);
        for (di, l) in rep.ledgers.iter().enumerate() {
            assert_eq!(
                l.submitted,
                l.done + l.cancelled,
                "router {router} device {di}: ledger imbalance"
            );
        }
        let c = &rep.counters;
        assert_eq!(
            c.flows,
            c.flows_finished + c.flows_dead,
            "router {router}: every flow finishes or is accounted dead"
        );
        assert!(c.flows_finished > 0, "router {router}: the trace must make progress");
    }
}

/// The overload regression (DESIGN.md §9): a deliberately tiny gate
/// forces every-device rejections, so turns take the re-route → park →
/// retry path — and still nothing admitted is silently dropped.
#[test]
fn no_admitted_turn_dropped_under_forced_overload() {
    for &router in route::names() {
        let geo = llama32_3b();
        let inputs = fleet_user_flows(
            &FleetSpec {
                users: 4,
                zipf_exponent: 0.5,
                chat_rate_per_s: 0.8,
                monitor_rate_per_s: 0.4,
                duration_s: 6.0,
                seed: 55,
                max_seq: geo.max_seq,
            },
            geo.vocab,
        );
        let total_turns: u64 = inputs.iter().map(|uf| uf.flow.turns.len() as u64).sum();
        let mut cfg = FleetConfig::new(2, router, geo, default_soc());
        cfg.seed = 55;
        cfg.overload.max_queue_depth = 2;
        cfg.overload.retry_after_ms = 50.0;
        let rep = Fleet::new(cfg).unwrap().run(inputs).unwrap();

        let c = &rep.counters;
        assert!(
            c.rejections > 0,
            "router {router}: the tiny gate must actually reject (got {c:?})"
        );
        assert_eq!(c.retries, c.rejections, "every parked turn is retried, once per park");
        // Turn accounting: a turn completes at most once; every turn is
        // covered by a completion, a cancel (migration bookkeeping or a
        // dead flow's in-flight kill), or a dead flow's shed record —
        // migration double-counts (cancel + done) only inflate the
        // left side, never hide a loss.
        let done: u64 = rep.ledgers.iter().map(|l| l.done).sum();
        let cancelled: u64 = rep.ledgers.iter().map(|l| l.cancelled).sum();
        assert!(done <= total_turns, "router {router}: a turn must finish at most once");
        assert!(
            done + cancelled + c.shed_turns >= total_turns,
            "router {router}: turn accounting must cover the whole trace \
             (done {done} + cancelled {cancelled} + shed {} < {total_turns})",
            c.shed_turns
        );
        if c.flows_dead == 0 {
            assert_eq!(done, total_turns, "router {router}: no deaths ⇒ every turn finishes");
        }
        assert_eq!(c.flows, c.flows_finished + c.flows_dead, "router {router}");
        assert!(c.flows_finished > 0, "router {router}: overload must not starve everyone");
    }
}
