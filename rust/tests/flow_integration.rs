//! Flow-level integration (DESIGN.md §3): multi-turn flows must reuse
//! cross-turn KV under the Agent.xpu engine — turn *k+1* prefills only
//! its delta tokens — while baselines running the *same* flow trace
//! recompute every conversation prefix, and the RunReport quantifies
//! the difference (per-flow latency, per-turn TTFT, prefix-cache
//! hit-rate, reused/recomputed token counters).

use agent_xpu::baselines::{Scheme, SingleXpuEngine};
use agent_xpu::config::{ModelGeometry, SchedulerConfig, default_soc, llama32_3b};
use agent_xpu::coordinator::AgentXpuEngine;
use agent_xpu::engine::Engine;
use agent_xpu::workload::{
    FlowBinding, FlowSpec, Priority, Request, flatten_flows, flow_trace, profile,
};

fn geo() -> ModelGeometry {
    let mut g = llama32_3b();
    g.n_layers = 4; // keep DES integration fast; geometry ratios intact
    g
}

/// A deterministic 3-turn reactive chat flow: 200-token opener, two
/// 60-token follow-ups, 8-token replies.
fn three_turn_flow() -> Vec<Request> {
    let (p0, out, delta) = (200usize, 8usize, 60usize);
    let mut turns = vec![];
    let mut prompt = vec![1i32; p0];
    for k in 0..3usize {
        if k > 0 {
            let ds = prompt.len() + out;
            prompt = vec![2; ds]; // placeholder prefix; driver stitches
            prompt.extend(vec![1; delta]);
        }
        turns.push(Request {
            id: k as u64,
            priority: Priority::Reactive,
            arrival_us: 0.0,
            prompt: prompt.clone(),
            max_new_tokens: out,
            profile: "chat".into(),
            flow: Some(FlowBinding::linear(
                1,
                k,
                3,
                if k == 0 { 0.0 } else { 40_000.0 },
                if k == 0 { 0 } else { prompt.len() - delta },
            )),
        });
    }
    turns
}

#[test]
fn cross_turn_kv_reuse_prefills_only_deltas() {
    let mut agent =
        AgentXpuEngine::synthetic(geo(), default_soc(), SchedulerConfig::default());
    let rep = agent.run(three_turn_flow()).unwrap();
    assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);

    // turn k+1 prefills only the delta beyond the retained prefix
    for m in rep.reqs.iter().filter(|m| m.turn_idx > 0) {
        assert!(m.cached_prefix_len > 0, "turn {} missed the session cache", m.turn_idx);
        assert_eq!(
            m.prefill_tokens,
            m.input_len - m.cached_prefix_len,
            "turn {} must prefill exactly its delta",
            m.turn_idx
        );
        // the reused prefix is the whole prior conversation minus the
        // one token recomputed for first-token logits
        assert!(m.cached_prefix_len + 1 >= m.input_len - 60 - 8);
    }
    assert!((rep.prefix_cache_hit_rate() - 1.0).abs() < 1e-9);
    assert_eq!(rep.session_evictions, 0);
}

#[test]
fn agent_engine_beats_full_recompute_baseline_on_the_same_flow_trace() {
    let trace = three_turn_flow();
    let mut agent =
        AgentXpuEngine::synthetic(geo(), default_soc(), SchedulerConfig::default());
    let ra = agent.run(trace.clone()).unwrap();
    let mut single = SingleXpuEngine::new(geo(), default_soc(), Scheme::ContinuousBatching);
    let rs = single.run(trace).unwrap();

    // the baseline ran the same flow semantics (stitched prompts, think
    // time) but recomputed every prefix
    assert_eq!(rs.reqs.iter().filter(|m| m.finished()).count(), 3);
    assert_eq!(rs.reused_prefix_tokens(), 0);
    for m in &rs.reqs {
        assert_eq!(m.prefill_tokens, m.input_len, "baseline recomputes fully");
    }

    // the recomputed-token counter quantifies the reuse win
    assert!(
        ra.recomputed_prefill_tokens() < rs.recomputed_prefill_tokens(),
        "agent {} vs baseline {}",
        ra.recomputed_prefill_tokens(),
        rs.recomputed_prefill_tokens()
    );

    // and RunReport exposes the per-flow rollup, improved end-to-end
    let (fa, fs) = (ra.flows(), rs.flows());
    assert_eq!((fa.len(), fs.len()), (1, 1));
    assert!(fa[0].finished && fs[0].finished);
    assert!(
        fa[0].e2e_us.unwrap() <= fs[0].e2e_us.unwrap(),
        "flow e2e: agent {} vs baseline {}",
        fa[0].e2e_us.unwrap(),
        fs[0].e2e_us.unwrap()
    );
    assert!(fa[0].mean_turn_ttft_ms <= fs[0].mean_turn_ttft_ms);
    // hit-rate lands in the serialized report too
    let j = ra.to_json();
    let flows = j.get("flows").unwrap();
    assert!(flows.get("prefix_cache_hit_rate").unwrap().as_f64().unwrap() > 0.99);
}

#[test]
fn generated_flow_traces_uphold_lifecycle_invariants_on_every_engine() {
    let g = geo();
    let chats = flow_trace(
        &FlowSpec {
            profile: profile("lmsys").unwrap(),
            flow_rate_per_s: 0.1,
            think_time_s: 5.0,
            turns: (2, 4),
            duration_s: 60.0,
            seed: 11,
            max_seq: g.max_seq,
        },
        Priority::Reactive,
        g.vocab,
        0,
        0,
    );
    let n: u64 = chats.iter().map(|f| f.total_turns() as u64).sum();
    let monitors = flow_trace(
        &FlowSpec {
            profile: profile("proactivebench").unwrap(),
            flow_rate_per_s: 0.08,
            think_time_s: 15.0,
            turns: (2, 3),
            duration_s: 60.0,
            seed: 12,
            max_seq: g.max_seq,
        },
        Priority::Proactive,
        g.vocab,
        n,
        1000,
    );
    let mut trace = flatten_flows(chats);
    trace.extend(flatten_flows(monitors));
    assert!(!trace.is_empty());
    let total = trace.len();

    let engines: Vec<Box<dyn Engine>> = vec![
        Box::new(AgentXpuEngine::synthetic(
            g.clone(),
            default_soc(),
            SchedulerConfig::default(),
        )),
        Box::new(SingleXpuEngine::new(g.clone(), default_soc(), Scheme::PreemptRestart)),
        Box::new(SingleXpuEngine::new(
            g.clone(),
            default_soc(),
            Scheme::ContinuousBatching,
        )),
        Box::new(agent_xpu::baselines::CpuFcfsEngine::new(g.clone(), default_soc(), 4)),
    ];
    for mut e in engines {
        let name = e.name();
        let rep = e.run(trace.clone()).unwrap_or_else(|x| panic!("{name}: {x:#}"));
        assert_eq!(
            rep.reqs.iter().filter(|m| m.finished()).count(),
            total,
            "{name} lost flow turns"
        );
        // turn ordering: within every flow, turn k+1 starts after k ends
        for f in rep.flows() {
            let turns: Vec<_> = rep
                .reqs
                .iter()
                .filter(|m| m.flow_id == Some(f.flow_id))
                .collect();
            for w in turns.windows(2) {
                assert!(
                    w[1].first_token_us.unwrap() > w[0].done_us.unwrap(),
                    "{name}: flow {} turn order violated",
                    f.flow_id
                );
                assert!(w[1].arrival_us >= w[0].done_us.unwrap());
            }
        }
    }
}

#[test]
fn map_reduce_dags_join_branches_and_reuse_the_trunk() {
    use agent_xpu::workload::{DagShape, DagSpec, dag_flow_trace};
    let g = geo();
    let flows = dag_flow_trace(
        &DagSpec {
            profile: profile("lmsys").unwrap(),
            flow_rate_per_s: 0.05,
            think_time_s: 3.0,
            shape: DagShape::MapReduce { fanout: 3 },
            duration_s: 80.0,
            seed: 5,
            max_seq: g.max_seq,
        },
        Priority::Proactive,
        g.vocab,
        0,
        0,
    );
    let trace = flatten_flows(flows);
    assert!(!trace.is_empty());
    let total = trace.len();
    let mut agent =
        AgentXpuEngine::synthetic(g, default_soc(), SchedulerConfig::default());
    let rep = agent.run(trace).unwrap();
    assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), total);
    // joins (≥ 2 predecessors) never start before every branch finished
    let mut by = std::collections::HashMap::new();
    for m in rep.reqs.iter().filter(|m| m.flow_id.is_some()) {
        by.insert((m.flow_id.unwrap(), m.turn_idx), m);
    }
    let mut joins = 0;
    for m in rep.reqs.iter().filter(|m| m.deps.len() >= 2) {
        joins += 1;
        for d in &m.deps {
            let dep = by[&(m.flow_id.unwrap(), *d)];
            assert!(
                m.arrival_us >= dep.done_us.unwrap() - 1e-6,
                "join {} released before branch {}",
                m.turn_idx,
                d
            );
        }
    }
    assert!(joins >= 1, "the trace must contain join turns");
    // tool nodes executed on the CPU; the session cache still carried
    // the conversation trunk across the tool hop into the branches
    assert!(rep.reqs.iter().any(|m| m.tool && m.finished()));
    assert!(rep.utilization("cpu") > 0.0);
    assert!(rep.reused_prefix_tokens() > 0, "trunk KV reuse across the DAG");
    // and the rollup's critical-path bound holds per flow
    for f in rep.flows() {
        assert!(f.finished);
        assert!(f.tool_turns >= 1);
        assert!(f.e2e_us.unwrap() + 1e-6 >= f.critical_path_us.unwrap());
    }
}

#[test]
fn flow_runs_are_deterministic() {
    let run = || {
        let mut e =
            AgentXpuEngine::synthetic(geo(), default_soc(), SchedulerConfig::default());
        e.run(three_turn_flow()).unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.makespan_us, b.makespan_us);
    assert_eq!(a.reused_prefix_tokens(), b.reused_prefix_tokens());
    for (x, y) in a.reqs.iter().zip(&b.reqs) {
        assert_eq!(x.first_token_us, y.first_token_us);
        assert_eq!(x.done_us, y.done_us);
        assert_eq!(x.cached_prefix_len, y.cached_prefix_len);
    }
}
