//! Integration over real artifacts (skipped when `make artifacts` has
//! not run): the full Agent.xpu engine with real PJRT compute must
//! produce exactly the tokens that plain sequential generation produces
//! — chunking, batching, backfill, and preemption are *schedule*
//! transformations, never *numerics* transformations.

use std::sync::Arc;

use agent_xpu::config::{SchedulerConfig, default_soc};
use agent_xpu::coordinator::AgentXpuEngine;
use agent_xpu::engine::{Engine, ExecBridge};
use agent_xpu::runtime::{ModelExecutor, Runtime};
use agent_xpu::server::{Server, client_generate};
use agent_xpu::workload::{Priority, Request};

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    dir.join("manifest.json").exists().then_some(dir)
}

fn mk_trace(vocab: usize) -> Vec<Request> {
    let prompt = |seed: usize, n: usize| -> Vec<i32> {
        (0..n).map(|i| ((i * 31 + seed * 17 + 3) % vocab) as i32).collect()
    };
    vec![
        Request {
            id: 1,
            priority: Priority::Proactive,
            arrival_us: 0.0,
            prompt: prompt(1, 40),
            max_new_tokens: 6,
            profile: "it".into(),
            flow: None,
        },
        Request {
            id: 2,
            priority: Priority::Reactive,
            arrival_us: 10.0,
            prompt: prompt(2, 21),
            max_new_tokens: 5,
            profile: "it".into(),
            flow: None,
        },
        Request {
            id: 3,
            priority: Priority::Proactive,
            arrival_us: 20.0,
            prompt: prompt(3, 17),
            max_new_tokens: 7,
            profile: "it".into(),
            flow: None,
        },
    ]
}

#[test]
fn scheduled_execution_matches_sequential_generation() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Arc::new(Runtime::load(&dir).unwrap());
    let trace = mk_trace(rt.geo.vocab);

    // ground truth: each request generated alone, sequentially
    let exec = ModelExecutor::new(rt.clone());
    let chunk = rt.geo.max_chunk();
    let expected: Vec<Vec<i32>> = trace
        .iter()
        .map(|r| exec.generate(&r.prompt, chunk, r.max_new_tokens).unwrap())
        .collect();

    // the full engine: concurrent, chunked, batched, preemptible
    let mut e = AgentXpuEngine::real(
        Arc::new(ModelExecutor::new(rt)),
        default_soc(),
        SchedulerConfig::default(),
    );
    let rep = e.run(trace.clone()).unwrap();
    assert_eq!(rep.reqs.len(), 3);
    for m in &rep.reqs {
        assert!(m.finished());
    }

    // token equality is checked through a *second* engine run whose
    // bridge records states... simpler: regenerate through the engine by
    // reading back the per-request tokens — the engine does not expose
    // them in RunReport, so re-run requests through the RT scheduler:
    let rt2 = Arc::new(Runtime::load(&dir).unwrap());
    let bridge = Arc::new(ExecBridge::real(Arc::new(ModelExecutor::new(rt2))));
    let (tx, rx) = std::sync::mpsc::channel();
    let sched = agent_xpu::server::RtScheduler::new(
        bridge,
        default_soc(),
        SchedulerConfig::default(),
    );
    let handles: Vec<std::sync::mpsc::Receiver<agent_xpu::server::TokenEvent>> = trace
        .iter()
        .map(|r| {
            let (etx, erx) = std::sync::mpsc::channel();
            tx.send(agent_xpu::server::RtMsg::Submit(agent_xpu::server::RtRequest {
                id: r.id,
                priority: r.priority,
                prompt: r.prompt.clone(),
                max_new_tokens: r.max_new_tokens,
                session: None,
                deps: vec![],
                events: etx,
            }))
            .unwrap();
            erx
        })
        .collect();
    drop(tx);
    sched.serve(rx).unwrap();
    for (erx, want) in handles.iter().zip(&expected) {
        let events: Vec<_> = erx.iter().collect();
        match events.last().unwrap() {
            agent_xpu::server::TokenEvent::Done { tokens, .. } => {
                assert_eq!(tokens, want, "batched/concurrent tokens must match sequential");
            }
            e => panic!("expected Done, got {e:?}"),
        }
    }
}

#[test]
fn uds_server_serves_real_model() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Arc::new(Runtime::load(&dir).unwrap());
    let vocab = rt.geo.vocab;
    let exec = ModelExecutor::new(rt.clone());
    let prompt: Vec<i32> = (0..19).map(|i| ((i * 23 + 1) % vocab) as i32).collect();
    let expected = exec.generate(&prompt, rt.geo.max_chunk(), 6).unwrap();

    let socket = std::env::temp_dir()
        .join(format!("agent-xpu-it-{}.sock", std::process::id()));
    let bridge = Arc::new(ExecBridge::real(Arc::new(ModelExecutor::new(rt))));
    let server =
        Server::new(bridge, &socket, default_soc(), SchedulerConfig::default());
    let s = socket.clone();
    std::thread::spawn(move || {
        let _ = server.run();
    });
    for _ in 0..400 {
        if s.exists() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let (tokens, ttft, total) =
        client_generate(&socket, &prompt, Priority::Reactive, 6).unwrap();
    assert_eq!(tokens, expected, "UDS-served tokens match direct generation");
    assert!(ttft > 0.0 && total >= ttft);
    let _ = std::fs::remove_file(socket);
}

#[test]
fn real_engine_deterministic_and_priority_ordered() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let run = || {
        let rt = Arc::new(Runtime::load(&dir).unwrap());
        let trace = mk_trace(rt.geo.vocab);
        let mut e = AgentXpuEngine::real(
            Arc::new(ModelExecutor::new(rt)),
            default_soc(),
            SchedulerConfig::default(),
        );
        e.run(trace).unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.makespan_us, b.makespan_us);
    for (x, y) in a.reqs.iter().zip(&b.reqs) {
        assert_eq!(x.first_token_us, y.first_token_us);
    }
}
