//! Crash-recovery properties of the write-ahead journal (DESIGN.md §7).
//!
//! The serving invariant under test: **no admitted turn is silently
//! dropped**.  A crash may land at any byte — mid-record, mid-fsync
//! batch, or on a clean boundary — and the journal must replay every
//! surviving prefix to a consistent state: decoded records are an exact
//! prefix of what was written, pending = submits − terminals with no
//! duplicates, and a torn tail is detected rather than misparsed.

use agent_xpu::server::journal::{
    BindRec, Journal, Record, Replay, SubmitRec, decode_records, encode_record,
    replay_records,
};
use agent_xpu::workload::Priority;

/// Deterministic LCG so the record mix is reproducible without a rand
/// dependency.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

/// A varied journal history: interleaved submits (some with sessions
/// and deps), terminal records for a subset, and session re-binds.
fn sample_history(seed: u64, n_submits: u64) -> Vec<Record> {
    let mut rng = Lcg(seed);
    let mut recs = vec![];
    let mut live: Vec<u64> = vec![];
    for id in 1..=n_submits {
        let session = if id % 3 == 0 { Some(format!("chat-{}", id % 2)) } else { None };
        let deps = if id > 2 && rng.next() % 4 == 0 { vec![id - 1, id - 2] } else { vec![] };
        let plen = 1 + (rng.next() % 7) as usize;
        recs.push(Record::Submit(SubmitRec {
            id,
            priority: if id % 2 == 0 { Priority::Reactive } else { Priority::Proactive },
            prompt: (0..plen).map(|p| (p as i32) + id as i32).collect(),
            max_new_tokens: 1 + (rng.next() % 16) as usize,
            session: session.clone(),
            deps,
        }));
        if let Some(tag) = session {
            recs.push(Record::Bind(BindRec {
                tag,
                flow_id: id % 2,
                calls: (id / 3) as usize,
                turn_of: vec![(id, (id / 3) as usize)],
            }));
        }
        live.push(id);
        // terminate a random earlier turn now and then
        if !live.is_empty() && rng.next() % 3 == 0 {
            let victim = live.remove((rng.next() as usize) % live.len());
            recs.push(match rng.next() % 3 {
                0 => Record::Done { id: victim },
                1 => Record::Cancelled { id: victim },
                _ => Record::Shed { id: victim },
            });
        }
    }
    recs
}

/// Expected pending set for a record prefix: submits minus terminals.
fn expected_pending(recs: &[Record]) -> Vec<u64> {
    let mut pending = std::collections::BTreeSet::new();
    for r in recs {
        match r {
            Record::Submit(s) => {
                pending.insert(s.id);
            }
            Record::Done { id } | Record::Cancelled { id } | Record::Shed { id } => {
                pending.remove(id);
            }
            Record::Bind(_) => {}
        }
    }
    pending.into_iter().collect()
}

fn assert_consistent(replay: &Replay, decoded: &[Record], context: &str) {
    let want = expected_pending(decoded);
    let got: Vec<u64> = replay.pending.iter().map(|s| s.id).collect();
    assert_eq!(got, want, "pending mismatch {context}");
    // no duplicates: every pending id appears exactly once
    let uniq: std::collections::BTreeSet<u64> = got.iter().copied().collect();
    assert_eq!(uniq.len(), got.len(), "duplicate pending ids {context}");
    let max_seen = decoded
        .iter()
        .map(|r| match r {
            Record::Submit(s) => s.id,
            Record::Done { id } | Record::Cancelled { id } | Record::Shed { id } => *id,
            Record::Bind(_) => 0,
        })
        .max()
        .unwrap_or(0);
    assert_eq!(replay.max_req_id, max_seen, "id floor mismatch {context}");
}

/// Crash-at-any-byte: every prefix of the encoded stream decodes to an
/// exact record prefix and replays to a consistent state.  This is the
/// property the ISSUE names: a torn final record is dropped, never
/// misparsed, and no terminal record survives without its submit.
#[test]
fn every_journal_prefix_replays_to_a_consistent_state() {
    let history = sample_history(0xA5EED, 24);
    let mut bytes = vec![];
    let mut boundaries = vec![0usize];
    for rec in &history {
        bytes.extend_from_slice(&encode_record(rec));
        boundaries.push(bytes.len());
    }
    for cut in 0..=bytes.len() {
        let (decoded, truncated) = decode_records(&bytes[..cut]);
        // decoded records are exactly the full records whose encoding
        // fits inside the cut
        let n_complete = boundaries.iter().filter(|&&b| b > 0 && b <= cut).count();
        assert_eq!(
            decoded.len(),
            n_complete,
            "cut at byte {cut}: wrong number of records"
        );
        assert_eq!(
            decoded[..],
            history[..n_complete],
            "cut at byte {cut}: decoded prefix diverges"
        );
        // a cut on a record boundary is clean; anything else is torn
        assert_eq!(truncated, !boundaries.contains(&cut), "cut at byte {cut}");
        let replay = replay_records(&decoded, truncated);
        assert_consistent(&replay, &decoded, &format!("(cut at byte {cut})"));
    }
}

/// Corrupting any single byte of a record must not let a wrong record
/// through: decode stops at (or cleanly skips past, for length/crc
/// fields that still frame correctly) the damaged record, and every
/// record it does return matches what was written.
#[test]
fn corrupt_bytes_never_yield_wrong_records() {
    let history = sample_history(0xBEEF, 12);
    let mut bytes = vec![];
    for rec in &history {
        bytes.extend_from_slice(&encode_record(rec));
    }
    let mut rng = Lcg(0xC0FFEE);
    for _ in 0..200 {
        let pos = (rng.next() as usize) % bytes.len();
        let mut dmg = bytes.clone();
        dmg[pos] ^= 0x40 | (rng.next() as u8 & 0x3F).max(1);
        let (decoded, _) = decode_records(&dmg);
        for (i, rec) in decoded.iter().enumerate() {
            assert_eq!(
                *rec, history[i],
                "corruption at byte {pos} surfaced a record that was never written"
            );
        }
    }
}

/// Crash/restart through the real file API: a journal dropped without
/// any clean shutdown — with a torn half-record appended, as a crash
/// mid-`write` would leave — reopens to the correct pending set, and
/// reopening compacts so a second open sees a clean (non-truncated)
/// tail with identical state.
#[test]
fn killed_journal_reopens_and_compacts() {
    let dir = std::env::temp_dir().join(format!("axpu-wal-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("turns.waj");

    let history = sample_history(0xD00D, 16);
    {
        let (mut j, replay) = Journal::open(&path, 4).unwrap();
        assert!(replay.pending.is_empty() && !replay.truncated);
        for rec in &history {
            j.append(rec).unwrap();
        }
        j.sync().unwrap();
        // no clean shutdown: the Journal is dropped here, and the
        // "crash" additionally tears the last record in half
    }
    let torn = encode_record(&Record::Submit(SubmitRec {
        id: 999,
        priority: Priority::Reactive,
        prompt: vec![1, 2, 3],
        max_new_tokens: 4,
        session: None,
        deps: vec![],
    }));
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&torn[..torn.len() / 2]).unwrap();
    }

    let want = expected_pending(&history);
    let (_j2, replay) = Journal::open(&path, 4).unwrap();
    assert!(replay.truncated, "the torn tail must be detected");
    let got: Vec<u64> = replay.pending.iter().map(|s| s.id).collect();
    assert_eq!(got, want, "torn turn 999 must not survive, admitted turns must");

    // open() compacted: a third open replays the same state cleanly
    drop(_j2);
    let (_j3, again) = Journal::open(&path, 4).unwrap();
    assert!(!again.truncated, "compaction must have dropped the torn tail");
    let got2: Vec<u64> = again.pending.iter().map(|s| s.id).collect();
    assert_eq!(got2, want);

    std::fs::remove_dir_all(&dir).ok();
}
