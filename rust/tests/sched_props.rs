//! Property-based tests over the coordinator and baselines: randomized
//! agentic traces (seeded — shrinking replaced by printing the failing
//! seed) checked against the scheduler's core invariants.
//!
//! Invariants (DESIGN.md §6):
//!  - completeness: every admitted request finishes with exactly its
//!    token budget; none lost, none duplicated;
//!  - per-XPU serialization: kernels on one XPU never overlap;
//!  - causality: arrival ≤ TTFT point ≤ completion;
//!  - determinism: identical traces → identical schedules;
//!  - priority: reactive requests see (much) lower normalized latency
//!    than proactive ones under mixed load;
//!  - **every policy in `engine::registry`** upholds the same
//!    lifecycle invariants on the same random traces — the engine
//!    loops below iterate the registry, so a newly registered policy
//!    (e.g. `deadline`) is covered automatically, with no test edits.

use agent_xpu::baselines::{CpuFcfsEngine, Scheme, SingleXpuEngine};
use agent_xpu::config::{ModelGeometry, SchedulerConfig, default_soc, llama32_3b};
use agent_xpu::coordinator::AgentXpuEngine;
use agent_xpu::engine::{Engine, EngineClock, EngineCore, EngineEvent, registry};
use agent_xpu::heg::{ElasticPlan, plan_chunks};
use agent_xpu::metrics::RunReport;
use agent_xpu::util::rng::Rng;
use agent_xpu::workload::{
    DagShape, DagSpec, Priority, Request, dag_flow_trace, flatten_flows, profile,
};

fn geo() -> ModelGeometry {
    let mut g = llama32_3b();
    g.n_layers = 3; // keep property sweeps fast; geometry ratios intact
    g
}

/// Every registered policy at the test geometry, by registry name.
fn registry_engines() -> Vec<Box<dyn EngineCore + Send>> {
    registry::names()
        .iter()
        .map(|n| {
            registry::build(n, geo(), default_soc(), SchedulerConfig::default())
                .expect("registered name builds")
        })
        .collect()
}

/// Order-insensitive-where-it-must-be, bit-exact-where-it-matters run
/// fingerprint: engine label, makespan, energy, counters, and every
/// request's lifecycle timestamps at full f64 precision.  Two runs
/// with equal fingerprints produced the same schedule.
fn fingerprint(rep: &RunReport) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for b in rep.engine.bytes() {
        mix(b as u64);
    }
    mix(rep.makespan_us.to_bits());
    mix(rep.total_energy_j.to_bits());
    for e in rep.energy_by_class {
        mix(e.to_bits());
    }
    mix(rep.frames_scheduled);
    mix(rep.frames_missed);
    mix(rep.preemptions);
    mix(rep.backfills);
    mix(rep.kv_evictions);
    mix(rep.session_evictions);
    mix(rep.rebinds);
    mix(rep.splits);
    mix(rep.split_tokens);
    for m in &rep.reqs {
        mix(m.id);
        mix(m.first_token_us.map(|v| v.to_bits()).unwrap_or(1));
        mix(m.done_us.map(|v| v.to_bits()).unwrap_or(1));
        mix(m.output_tokens as u64);
        mix(m.prefill_tokens as u64);
        mix(m.cached_prefix_len as u64);
    }
    h
}

/// Random mixed trace: 3–14 requests, mixed priorities, bursty arrivals.
fn random_trace(seed: u64) -> Vec<Request> {
    let g = geo();
    let mut r = Rng::new(seed);
    let n = r.usize(3, 15);
    let mut t = 0.0f64;
    (0..n as u64)
        .map(|i| {
            t += r.exponential(1.0 / 0.4) * 1e6; // ~0.4 req/s
            let reactive = r.f64() < 0.3;
            let plen = r.usize(4, g.max_seq / 2);
            Request {
                id: i,
                priority: if reactive { Priority::Reactive } else { Priority::Proactive },
                arrival_us: t,
                prompt: vec![1; plen],
                max_new_tokens: r.usize(1, 24),
                profile: "prop".into(),
                flow: None,
            }
        })
        .collect()
}

fn check_lifecycle(rep: &RunReport, trace: &[Request]) {
    assert_eq!(rep.reqs.len(), trace.len(), "request count");
    for (m, q) in rep.reqs.iter().zip(trace.iter()) {
        assert_eq!(m.id, q.id);
        assert!(m.finished(), "req {} unfinished", m.id);
        assert_eq!(m.output_tokens, q.max_new_tokens, "req {} tokens", m.id);
        let ttft = m.first_token_us.unwrap();
        let done = m.done_us.unwrap();
        assert!(ttft > m.arrival_us, "req {} ttft before arrival", m.id);
        assert!(done >= ttft, "req {} done before first token", m.id);
        assert!(done <= rep.makespan_us + 1e-6);
    }
    // busy time cannot exceed makespan per XPU
    for x in &rep.xpus {
        assert!(
            x.busy_us <= rep.makespan_us + 1.0,
            "{} busy {} > makespan {}",
            x.name,
            x.busy_us,
            rep.makespan_us
        );
    }
    assert!(rep.total_energy_j >= 0.0 && rep.total_energy_j.is_finite());
    // the energy books close: per-class attribution (reactive /
    // proactive / graphics / idle) sums to the total on every engine
    let class_sum: f64 = rep.energy_by_class.iter().sum();
    assert!(
        (class_sum - rep.total_energy_j).abs() <= 1e-6 * rep.total_energy_j.max(1.0),
        "energy attribution must close: {} vs {}",
        class_sum,
        rep.total_energy_j
    );
}

#[test]
fn agent_xpu_lifecycle_invariants_hold_over_random_traces() {
    for seed in 0..40 {
        let trace = random_trace(seed);
        let mut e =
            AgentXpuEngine::synthetic(geo(), default_soc(), SchedulerConfig::default());
        let rep = e.run(trace.clone()).unwrap_or_else(|x| panic!("seed {seed}: {x:#}"));
        check_lifecycle(&rep, &trace);
        // kernels never overlap on an XPU
        e.last_trace().unwrap().assert_serialized();
    }
}

#[test]
fn all_registered_policies_uphold_lifecycle_on_same_traces() {
    for seed in 0..12 {
        let trace = random_trace(1000 + seed);
        for mut e in registry_engines() {
            let name = e.name();
            let rep = e
                .run(trace.clone())
                .unwrap_or_else(|x| panic!("seed {seed} engine {name}: {x:#}"));
            check_lifecycle(&rep, &trace);
            // per-XPU serialization holds for every policy's trace
            // (trace retention now lives in the shared PolicyEngine)
            e.last_trace()
                .unwrap_or_else(|| panic!("{name}: trace retained"))
                .assert_serialized();
        }
    }
}

#[test]
fn schedules_are_deterministic_per_seed() {
    for seed in 0..10 {
        let run = || {
            let mut e = AgentXpuEngine::synthetic(
                geo(),
                default_soc(),
                SchedulerConfig::default(),
            );
            e.run(random_trace(2000 + seed)).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.makespan_us, b.makespan_us, "seed {seed}");
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.backfills, b.backfills);
        for (x, y) in a.reqs.iter().zip(&b.reqs) {
            assert_eq!(x.first_token_us, y.first_token_us, "seed {seed} req {}", x.id);
            assert_eq!(x.done_us, y.done_us);
        }
    }
}

/// §6 determinism, extended across the API redesign: the incremental
/// `submit`/`step` loop must reproduce the batch `run()` RunReport
/// bit-for-bit on **every registered policy** — the real-time server
/// drives the same code path, so this is the serving/simulation parity
/// proof, and a newly registered policy joins the gate automatically.
#[test]
fn incremental_submit_step_matches_batch_run_bit_for_bit() {
    let mk_all = || registry_engines();
    for seed in [7u64, 404] {
        let trace = random_trace(5000 + seed);
        for (mut batch, mut incr) in mk_all().into_iter().zip(mk_all()) {
            let name = batch.name();
            let a = batch.run(trace.clone()).unwrap();

            incr.start(EngineClock::Virtual).unwrap();
            for r in trace.clone() {
                incr.submit(r).unwrap();
            }
            let events = incr.drain().unwrap();
            let b = incr.finish().unwrap();

            assert_eq!(a.makespan_us, b.makespan_us, "{name} seed {seed}: makespan");
            assert_eq!(a.preemptions, b.preemptions, "{name} seed {seed}");
            assert_eq!(a.backfills, b.backfills, "{name} seed {seed}");
            assert_eq!(a.kv_evictions, b.kv_evictions, "{name} seed {seed}");
            assert_eq!(a.total_energy_j, b.total_energy_j, "{name} seed {seed}");
            assert_eq!(a.reqs.len(), b.reqs.len(), "{name} seed {seed}");
            for (x, y) in a.reqs.iter().zip(&b.reqs) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.first_token_us, y.first_token_us, "{name} req {}", x.id);
                assert_eq!(x.done_us, y.done_us, "{name} req {}", x.id);
                assert_eq!(x.output_tokens, y.output_tokens, "{name} req {}", x.id);
                assert_eq!(x.prefill_tokens, y.prefill_tokens, "{name} req {}", x.id);
            }

            // the event stream is complete: one Admitted and one
            // TurnDone per request, one TokenEmitted per token
            let count = |f: fn(&EngineEvent) -> bool| events.iter().filter(|e| f(e)).count();
            assert_eq!(
                count(|e| matches!(e, EngineEvent::Admitted { .. })),
                trace.len(),
                "{name} seed {seed}: admissions"
            );
            assert_eq!(
                count(|e| matches!(e, EngineEvent::TurnDone { .. })),
                trace.len(),
                "{name} seed {seed}: completions"
            );
            assert_eq!(
                count(|e| matches!(e, EngineEvent::TokenEmitted { .. })),
                b.total_tokens(),
                "{name} seed {seed}: token events"
            );
        }
    }
}

/// The API-redesign equivalence gate, part 1 of 2 (the PR 2 pattern
/// applied across the constructor surface): for every pre-existing
/// engine family, the registry-built engine must reproduce exactly the
/// RunReport the family's historical constructor produces — same
/// makespan, energy, counters, and per-request timestamps at full f64
/// precision.  This pins registry wiring (names, configs, the
/// cpu-fcfs concurrency constant) to the constructors; equivalence
/// with the *pre-refactor* engines additionally rests on the port
/// reusing the unchanged `coordinator::select`/`memory`/`dispatch`
/// helpers verbatim and on the §6 invariant suite above, since both
/// sides here are `PolicyEngine` builds.  Part 2
/// (`every_registered_policy_is_deterministic_on_seeded_traces`) pins
/// the schedules themselves against run-to-run drift.
#[test]
fn registry_engines_reproduce_family_constructors_bit_for_bit() {
    let mut frames: Vec<(String, Box<dyn Engine + Send>, Box<dyn Engine + Send>)> = vec![
        (
            "agent-xpu".into(),
            Box::new(AgentXpuEngine::synthetic(
                geo(),
                default_soc(),
                SchedulerConfig::default(),
            )),
            registry::build("agent-xpu", geo(), default_soc(), SchedulerConfig::default())
                .unwrap(),
        ),
        (
            "cpu-fcfs".into(),
            Box::new(CpuFcfsEngine::new(geo(), default_soc(), 4)),
            registry::build("cpu-fcfs", geo(), default_soc(), SchedulerConfig::default())
                .unwrap(),
        ),
    ];
    for (name, scheme) in [
        ("scheme-a", Scheme::PreemptRestart),
        ("scheme-b", Scheme::TimeShare),
        ("scheme-c", Scheme::ContinuousBatching),
    ] {
        frames.push((
            name.into(),
            Box::new(SingleXpuEngine::new(geo(), default_soc(), scheme)),
            registry::build(name, geo(), default_soc(), SchedulerConfig::default())
                .unwrap(),
        ));
    }
    for seed in [7u64, 404, 2025] {
        let trace = random_trace(8000 + seed);
        for (name, direct, via_registry) in frames.iter_mut() {
            let a = direct.run(trace.clone()).unwrap();
            let b = via_registry.run(trace.clone()).unwrap();
            assert_eq!(a.engine, b.engine, "{name} seed {seed}: label");
            assert_eq!(
                fingerprint(&a),
                fingerprint(&b),
                "{name} seed {seed}: registry engine diverged from the \
                 family constructor"
            );
        }
    }
}

/// The display workload is part of the DES: graphics-enabled runs are
/// exactly as deterministic as bare ones, and the governor knobs at
/// their defaults change nothing even while frames contend.
#[test]
fn graphics_runs_are_deterministic_and_account_frames() {
    use agent_xpu::soc::GraphicsConfig;
    for seed in [3, 9] {
        let trace = random_trace(seed);
        let run = || {
            let mut e = AgentXpuEngine::synthetic(
                geo(),
                default_soc(),
                SchedulerConfig::default(),
            );
            e.set_graphics(Some(GraphicsConfig::default()));
            let rep = e.run(trace.clone()).unwrap();
            check_lifecycle(&rep, &trace);
            assert!(rep.frames_scheduled > 0, "seed {seed}: frames rendered");
            (fingerprint(&rep), rep.frames_scheduled, rep.frames_missed)
        };
        assert_eq!(run(), run(), "seed {seed}");
    }
}

/// The governor engaged (duty cap + vsync yield) on every random trace:
/// nothing is lost — the starvation valve turns every veto into a
/// deferral.
#[test]
fn engaged_duty_governor_never_loses_requests() {
    use agent_xpu::soc::GraphicsConfig;
    for seed in 0..10 {
        let trace = random_trace(seed);
        let mut sched = SchedulerConfig::default();
        sched.igpu_duty_cap = 0.3;
        sched.yield_to_graphics = true;
        let mut e = AgentXpuEngine::synthetic(geo(), default_soc(), sched);
        e.set_graphics(Some(GraphicsConfig::default()));
        let rep = e.run(trace.clone()).unwrap();
        check_lifecycle(&rep, &trace);
    }
}

#[test]
fn every_registered_policy_is_deterministic_on_seeded_traces() {
    for seed in [5u64, 61] {
        for trace in [random_trace(6000 + seed), random_dag_trace(6100 + seed)] {
            let run_all = || -> Vec<u64> {
                registry_engines()
                    .iter_mut()
                    .map(|e| fingerprint(&e.run(trace.clone()).unwrap()))
                    .collect()
            };
            assert_eq!(run_all(), run_all(), "seed {seed}: schedules must be stable");
        }
    }
}

/// Random workflow-DAG trace: one seeded DAG stream of a random shape
/// (tool-call nodes, fan-out/join) plus single-shot background traffic.
fn random_dag_trace(seed: u64) -> Vec<Request> {
    let g = geo();
    let mut r = Rng::new(seed);
    let shapes = [
        DagShape::ToolAgent { rounds: 2 },
        DagShape::MapReduce { fanout: 3 },
        DagShape::MonitorTools { wakeups: 2 },
    ];
    let shape = *r.choice(&shapes);
    let priority =
        if r.f64() < 0.5 { Priority::Reactive } else { Priority::Proactive };
    let flows = dag_flow_trace(
        &DagSpec {
            profile: profile("lmsys").unwrap(),
            flow_rate_per_s: 0.06,
            think_time_s: 4.0,
            shape,
            duration_s: 60.0,
            seed,
            max_seq: g.max_seq,
        },
        priority,
        g.vocab,
        0,
        0,
    );
    let mut trace = flatten_flows(flows);
    trace.extend(random_trace(seed + 77).into_iter().map(|mut q| {
        q.id += 100_000; // keep ids disjoint from the DAG stream
        q
    }));
    trace
}

/// DESIGN.md §6 generalized flow-ordering invariant: no workflow node
/// starts before *all* its DAG predecessors complete plus its
/// think-time — property-checked on every engine family over random
/// DAG workloads with tool-call nodes and fan-out/join turns.
#[test]
fn dag_ordering_invariant_holds_on_every_engine() {
    for seed in [3u64, 41, 99, 256] {
        let trace = random_dag_trace(seed);
        let n = trace.len();
        if trace.iter().all(|q| q.flow.is_none()) {
            continue; // no DAG flow landed in this seed's window
        }
        for mut e in registry_engines() {
            let name = e.name();
            let rep = e
                .run(trace.clone())
                .unwrap_or_else(|x| panic!("seed {seed} engine {name}: {x:#}"));
            assert_eq!(
                rep.reqs.iter().filter(|m| m.finished()).count(),
                n,
                "{name} seed {seed}: lost workflow nodes"
            );
            let mut by = std::collections::HashMap::new();
            for m in rep.reqs.iter().filter(|m| m.flow_id.is_some()) {
                by.insert((m.flow_id.unwrap(), m.turn_idx), m);
            }
            for m in rep.reqs.iter().filter(|m| m.flow_id.is_some()) {
                assert!(m.first_token_us.unwrap() >= m.arrival_us - 1e-6);
                for d in &m.deps {
                    let dep = by[&(m.flow_id.unwrap(), *d)];
                    assert!(
                        m.arrival_us >= dep.done_us.unwrap() + m.think_time_us - 1e-6,
                        "{name} seed {seed}: flow {:?} node {} started before \
                         predecessor {} completed + think-time",
                        m.flow_id,
                        m.turn_idx,
                        d
                    );
                }
            }
            // tool nodes ran (on the CPU) and completed like any node
            if trace.iter().any(|q| q.is_tool()) {
                assert!(
                    rep.reqs.iter().any(|m| m.tool && m.finished()),
                    "{name} seed {seed}: tool nodes vanished"
                );
            }
        }
    }
}

#[test]
fn reactive_latency_dominates_proactive_under_load() {
    // aggregate over seeds: mixed loads where both classes appear
    let mut rt_sum = 0.0;
    let mut pro_sum = 0.0;
    let mut n = 0;
    for seed in 0..20 {
        let trace = random_trace(3000 + seed);
        let has_both = trace.iter().any(|r| r.priority == Priority::Reactive)
            && trace.iter().any(|r| r.priority == Priority::Proactive);
        if !has_both {
            continue;
        }
        let mut e =
            AgentXpuEngine::synthetic(geo(), default_soc(), SchedulerConfig::default());
        let rep = e.run(trace).unwrap();
        let r = rep.class(Priority::Reactive);
        let p = rep.class(Priority::Proactive);
        if r.mean_norm_latency_ms.is_finite() && p.mean_norm_latency_ms.is_finite() {
            rt_sum += r.mean_norm_latency_ms;
            pro_sum += p.mean_norm_latency_ms;
            n += 1;
        }
    }
    assert!(n >= 5, "not enough mixed seeds ({n})");
    assert!(
        rt_sum <= pro_sum,
        "reactive norm-lat {rt_sum} must not exceed proactive {pro_sum} in aggregate"
    );
}

/// Satellite: the coordinator's inter-XPU backfill candidates now come
/// from the driver's incrementally maintained waiting-proactive-prefill
/// index instead of a per-step scan of every live request.  The engine
/// `debug_assert`s index == scan at *every* scheduling decision (both
/// the prefill pipeline and the backfill path), so driving seeded
/// backfill-heavy traces through a debug test build proves the
/// schedules stay bit-identical to the scan version; the double run
/// pins determinism on top.
#[test]
fn backfill_index_matches_state_scan_on_backfill_heavy_traces() {
    for seed in [1u64, 13, 64] {
        let mut r = Rng::new(seed);
        let mut trace: Vec<Request> = (0..12u64)
            .map(|i| Request {
                id: i,
                priority: Priority::Proactive,
                arrival_us: i as f64 * 5_000.0,
                prompt: vec![1; r.usize(260, 800)],
                max_new_tokens: r.usize(4, 10),
                profile: "bf".into(),
                flow: None,
            })
            .collect();
        for i in 0..6u64 {
            trace.push(Request {
                id: 100 + i,
                priority: Priority::Reactive,
                arrival_us: i as f64 * 20_000.0,
                prompt: vec![1; 200],
                max_new_tokens: 6,
                profile: "bf".into(),
                flow: None,
            });
        }
        let run = || {
            let mut e = AgentXpuEngine::synthetic(
                geo(),
                default_soc(),
                SchedulerConfig::default(),
            );
            e.run(trace.clone()).unwrap()
        };
        let (a, b) = (run(), run());
        assert!(a.backfills >= 1, "seed {seed}: scenario must exercise backfill");
        assert_eq!(a.makespan_us, b.makespan_us, "seed {seed}");
        for (x, y) in a.reqs.iter().zip(&b.reqs) {
            assert_eq!(x.done_us, y.done_us, "seed {seed} req {}", x.id);
        }
    }
}

#[test]
fn ablations_never_lose_requests() {
    for seed in [11u64, 47, 90] {
        let trace = random_trace(seed);
        for (b, p, dg) in [
            (false, false, false),
            (false, true, false),
            (true, false, true),
            (false, true, true),
            (true, true, false),
        ] {
            let sched = SchedulerConfig {
                backfill: b,
                preemption: p,
                disaggregation: dg,
                ..Default::default()
            };
            let mut e = AgentXpuEngine::synthetic(geo(), default_soc(), sched);
            let rep = e
                .run(trace.clone())
                .unwrap_or_else(|x| panic!("seed {seed} b={b} p={p} dg={dg}: {x:#}"));
            check_lifecycle(&rep, &trace);
        }
    }
}

#[test]
fn chunk_plans_cover_every_prompt_exactly() {
    let g = llama32_3b();
    let mut r = Rng::new(99);
    for _ in 0..500 {
        let len = r.usize(1, g.max_seq + 1);
        let cap = *r.choice(&g.chunk_sizes);
        let plan = plan_chunks(&g, len, cap);
        let total: usize = plan.iter().map(|c| c.valid).sum();
        assert_eq!(total, len);
        let mut pos = 0;
        for (i, c) in plan.iter().enumerate() {
            assert_eq!(c.pos, pos, "len {len} cap {cap}");
            assert!(c.valid >= 1 && c.valid <= c.variant);
            assert!(c.variant <= cap.max(*g.chunk_sizes.iter().min().unwrap()));
            assert!(g.chunk_sizes.contains(&c.variant));
            if c.dynamic {
                assert_eq!(i, plan.len() - 1, "only the margin may be dynamic");
            }
            pos += c.valid;
        }
    }
}

/// The elastic-binding invariant (DESIGN.md §5): no sequence of
/// mid-flight re-bindings — advancing, rewinding, replanning from an
/// arbitrary position, splitting a pending chunk across XPUs, folding
/// the margin — may ever lose, duplicate, or reorder a prompt token.
/// Pending chunks must always tile `[cursor .. prompt_len)` exactly.
#[test]
fn elastic_plans_keep_coverage_exact_under_random_rebinding() {
    let g = llama32_3b();
    let mut r = Rng::new(4242);
    for _ in 0..300 {
        let len = r.usize(1, g.max_seq + 1);
        let cap = *r.choice(&g.chunk_sizes);
        let mut p = ElasticPlan::plan(&g, len, cap, 0);
        for _ in 0..40 {
            match r.usize(0, 5) {
                0 => {
                    if !p.done() {
                        p.advance_layer(g.n_layers);
                    }
                }
                1 => p.rewind(),
                2 => {
                    let from = r.usize(0, len);
                    let cap2 = *r.choice(&g.chunk_sizes);
                    p.replan(&g, from, cap2);
                }
                3 => {
                    if !p.done() {
                        let idx = r.usize(p.chunk_idx(), p.len());
                        let ratio = 0.1 + 0.8 * r.f64();
                        // None (started / dynamic / too small) is fine —
                        // the plan must simply be unchanged then
                        let _ = p.split(&g, idx, ratio);
                    }
                }
                _ => {
                    let _ = p.fold_margin(&g);
                }
            }
            // coverage: contiguous positions, each token planned once,
            // the tiling ending exactly at prompt_len
            let chunks = p.chunks();
            assert!(!chunks.is_empty() || p.pending_tokens() == 0);
            let mut pos = chunks.first().map(|c| c.pos);
            for c in chunks {
                assert!(c.valid >= 1 && c.valid <= c.variant, "len {len}: corrupt chunk");
                assert_eq!(Some(c.pos), pos, "len {len}: coverage not contiguous");
                pos = Some(c.pos + c.valid);
            }
            if let Some(end) = pos {
                assert_eq!(end, len, "plan must end at prompt_len");
            }
            // Σ valid over pending chunks == tokens left of the cursor
            match p.current() {
                Some(cur) => assert_eq!(p.pending_tokens(), len - cur.pos),
                None => {
                    assert!(p.done());
                    assert_eq!(p.pending_tokens(), 0);
                }
            }
        }
    }
}

/// Elastic re-binding under memory pressure: tiny DRAM forces
/// preemption and eviction-restart on random traces, so folds, splits,
/// replans, and restarts all interleave.  Every registry policy must
/// keep the lifecycle invariants (no token lost or duplicated), and
/// only the elastic engine may ever re-bind — the hook's `Never`
/// default keeps every other policy bit-static.
#[test]
fn elastic_rebinding_preserves_lifecycle_for_every_policy_under_pressure() {
    let g = geo();
    let mut soc = default_soc();
    let weights_gb = g.n_params() as f64 * g.weight_bytes / 1e9;
    let kv_gb = (2 * g.n_layers * g.cache_elems() * 4) as f64 / 1e9;
    soc.dram_gb = weights_gb + 2.2 * kv_gb;
    for seed in [3u64, 11, 29] {
        let trace = random_trace(2000 + seed);
        for &name in registry::names() {
            let mut e =
                registry::build(name, g.clone(), soc.clone(), SchedulerConfig::default())
                    .expect("registered name builds");
            let rep = e
                .run(trace.clone())
                .unwrap_or_else(|x| panic!("{name} seed {seed}: {x:#}"));
            check_lifecycle(&rep, &trace);
            // counter consistency: a split is a rebind that moved tokens
            assert!(rep.splits <= rep.rebinds, "{name}: splits exceed rebinds");
            assert_eq!(
                rep.splits == 0,
                rep.split_tokens == 0,
                "{name}: split/token counters disagree"
            );
            if name != "agent-xpu" {
                assert_eq!(rep.rebinds, 0, "{name} must never re-bind");
            }
        }
    }
}

#[test]
fn extreme_loads_still_complete() {
    let g = geo();
    // burst: everything arrives at t=0
    let burst: Vec<Request> = (0..30u64)
        .map(|i| Request {
            id: i,
            priority: if i % 4 == 0 { Priority::Reactive } else { Priority::Proactive },
            arrival_us: 0.0,
            prompt: vec![1; 64 + (i as usize * 37) % 900],
            max_new_tokens: 1 + (i as usize % 20),
            profile: "burst".into(),
            flow: None,
        })
        .collect();
    let mut e = AgentXpuEngine::synthetic(g.clone(), default_soc(), SchedulerConfig::default());
    let rep = e.run(burst.clone()).unwrap();
    check_lifecycle(&rep, &burst);

    // pathological: max-length prompts, single-token outputs
    let long: Vec<Request> = (0..4u64)
        .map(|i| Request {
            id: i,
            priority: Priority::Proactive,
            arrival_us: i as f64,
            prompt: vec![1; g.max_seq],
            max_new_tokens: 1,
            profile: "long".into(),
            flow: None,
        })
        .collect();
    let mut e = AgentXpuEngine::synthetic(g, default_soc(), SchedulerConfig::default());
    let rep = e.run(long.clone()).unwrap();
    check_lifecycle(&rep, &long);
}

#[test]
fn starvation_prevention_bounds_proactive_wait() {
    // a constant reactive stream + one proactive task: aging must let
    // the proactive task finish while reactive traffic continues
    let g = geo();
    let mut trace = vec![Request {
        id: 0,
        priority: Priority::Proactive,
        arrival_us: 0.0,
        prompt: vec![1; 1024],
        max_new_tokens: 4,
        profile: "victim".into(),
        flow: None,
    }];
    for i in 0..30u64 {
        trace.push(Request {
            id: 1 + i,
            priority: Priority::Reactive,
            arrival_us: 10_000.0 + i as f64 * 400_000.0,
            prompt: vec![1; 256],
            max_new_tokens: 6,
            profile: "stream".into(),
            flow: None,
        });
    }
    let mut e = AgentXpuEngine::synthetic(g, default_soc(), SchedulerConfig::default());
    let rep = e.run(trace).unwrap();
    let victim = rep.reqs.iter().find(|m| m.id == 0).unwrap();
    assert!(victim.finished(), "proactive task starved");
    let last_reactive_done = rep
        .reqs
        .iter()
        .filter(|m| m.priority == Priority::Reactive)
        .map(|m| m.done_us.unwrap())
        .fold(0.0f64, f64::max);
    assert!(
        victim.done_us.unwrap() < last_reactive_done,
        "aging must promote the proactive task before the stream ends"
    );
}

#[test]
fn memory_governor_keeps_everything_completing_under_tiny_dram() {
    // Shrink DRAM so only ~2 KV slots fit beyond the weights: the
    // governor must serialize starts (and evict for reactive arrivals)
    // without ever losing a request.
    let g = geo();
    let mut soc = default_soc();
    let weights_gb = g.n_params() as f64 * g.weight_bytes / 1e9;
    let kv_gb = (2 * g.n_layers * g.cache_elems() * 4) as f64 / 1e9;
    soc.dram_gb = weights_gb + 2.2 * kv_gb;
    for seed in [5u64, 21, 77] {
        let trace = random_trace(seed);
        let mut e =
            AgentXpuEngine::synthetic(g.clone(), soc.clone(), SchedulerConfig::default());
        let rep = e
            .run(trace.clone())
            .unwrap_or_else(|x| panic!("seed {seed}: {x:#}"));
        check_lifecycle(&rep, &trace);
    }
}
