// Fixture: `lock-hygiene` must fire on the poison-propagating unwrap.

pub fn read(stats: &Mutex<u64>) -> u64 {
    *stats.lock().unwrap()
}
