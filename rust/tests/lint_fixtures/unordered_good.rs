// Fixture: order-insensitive reductions over unordered maps pass —
// the chain analysis sees through transparent adapters to order-free
// terminals.

pub fn total(m: &FxHashMap<u64, u64>) -> u64 {
    m.values().copied().sum()
}

pub fn has_big(m: &FxHashMap<u64, u64>) -> bool {
    m.values().any(|v| *v > 10)
}

pub fn size(set: &FxHashSet<u64>) -> usize {
    set.iter().count()
}

pub fn live(m: &FxHashMap<u64, u64>) -> usize {
    m.values().filter(|v| **v > 0).count()
}
