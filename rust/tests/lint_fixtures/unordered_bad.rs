// Fixture: `no-unordered-iteration` must fire on order-sensitive map
// walks — a first-element read and a bare for-loop over a set.

pub fn first_key(m: &FxHashMap<u64, u64>) -> Option<u64> {
    m.keys().next().copied()
}

pub fn walk(set: &FxHashSet<u64>) {
    for _x in &set {
        touch();
    }
}
