// Fixture: the hot path returns errors instead of panicking.

pub fn pick(xs: &[u64]) -> Result<u64> {
    xs.first().copied().context("empty batch")
}

pub fn second(xs: &[u64]) -> Result<u64> {
    match xs.get(1) {
        Some(v) => Ok(*v),
        None => bail!("needs two"),
    }
}
