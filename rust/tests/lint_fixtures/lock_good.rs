// Fixture: poison-safe lock access passes.

pub fn read(stats: &Mutex<u64>) -> u64 {
    *stats.lock().unwrap_or_else(PoisonError::into_inner)
}
