// Fixture: `json-hygiene` must fire on the raw float constructor in a
// serializer path.

pub fn row(x: f64) -> Json {
    Json::obj().set("x", Json::Num(x))
}
