// Fixture: `panic-free-hot-path` must fire on all four panic forms in
// non-test code and stay silent inside the test fn.

pub fn pick(xs: &[u64]) -> u64 {
    *xs.first().unwrap()
}

pub fn second(xs: &[u64]) -> u64 {
    *xs.get(1).expect("needs two")
}

pub fn boom() {
    panic!("no");
}

pub fn later() {
    todo!()
}

#[test]
fn unwrap_in_tests_is_fine() {
    Some(1).unwrap();
}
