// Mini policy corpus: one registered and one unregistered impl per
// trait.  `registry-coverage` must flag exactly the Bad pair.

pub struct GoodPolicy;

impl SchedPolicy for GoodPolicy {}

pub struct BadPolicy;

impl SchedPolicy for BadPolicy {}

pub struct GoodRouter;

impl RoutePolicy for GoodRouter {}

pub struct BadRouter;

impl RoutePolicy for BadRouter {}

#[cfg(test)]
mod tests {
    struct TestOnlyPolicy;

    // impls inside test modules are exempt — test doubles need not be
    // registered.
    impl SchedPolicy for TestOnlyPolicy {}
}
