// Allow-mechanism fixture, in a core path so `no-wall-clock` applies.
// One properly allowed site, one stale allow, one reasonless allow.

pub fn epoch() -> Instant {
    // lint:allow(no-wall-clock) fixture: sanctioned epoch read
    Instant::now()
}

// lint:allow(no-wall-clock) fixture: stale escape matching nothing
pub fn clean() {}

pub fn reasonless() -> Instant {
    Instant::now() // lint:allow(no-wall-clock)
}
