// Mini registry fixture: names GoodPolicy, never BadPolicy.

pub use crate::policies::GoodPolicy;

pub fn build(name: &str) -> Option<GoodPolicy> {
    match name {
        "good" => Some(GoodPolicy),
        _ => None,
    }
}
