// Mini route registry fixture: names GoodRouter, never BadRouter.

pub use crate::policies::GoodRouter;

pub fn build(name: &str) -> Option<GoodRouter> {
    match name {
        "good" => Some(GoodRouter),
        _ => None,
    }
}
