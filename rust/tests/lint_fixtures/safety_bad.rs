// Fixture: `safety-comments` must fire on the bare unsafe block and
// the bare unsafe impl.

pub fn cast(data: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

unsafe impl Send for Wrapper {}
