// Fixture: non-finite-safe float serialization passes.

pub fn row(x: f64) -> Json {
    Json::obj().set("x", Json::num_or_null(x))
}
