// Fixture: core code reading the engine clock — no wall access.

pub fn now_us(clock: &EngineClock) -> u64 {
    clock.now_us()
}
