// Fixture: justified unsafe passes, including a Send+Sync pair sharing
// one SAFETY comment (the scan steps over unsafe-impl header lines).

pub fn cast(data: &[f32]) -> &[u8] {
    // SAFETY: fixture — the slice is valid for len * 4 bytes and u8
    // has no alignment requirement.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

// SAFETY: fixture — the wrapper owns its pointer exclusively.
unsafe impl Send for Wrapper {}
unsafe impl Sync for Wrapper {}

pub fn trailing(p: *const u8) -> u8 {
    unsafe { *p } // SAFETY: caller contract — p is valid and aligned
}
