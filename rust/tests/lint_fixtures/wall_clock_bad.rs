// Fixture: `no-wall-clock` must fire on the wall read in core code and
// stay silent inside the test module.  Never compiled — scanned only.

pub fn now_us() -> u64 {
    let t = Instant::now();
    t.elapsed().as_micros() as u64
}

#[cfg(test)]
mod tests {
    #[test]
    fn wall_in_tests_is_fine() {
        let _t = SystemTime::now();
    }
}
