//! The streaming EngineCore API (DESIGN.md §7): incremental
//! submission, the event stream, cancellation in every lifecycle
//! stage, and wall-clock runs — the surface the real-time server
//! drives, exercised here deterministically on the virtual clock.

use agent_xpu::config::{ModelGeometry, SchedulerConfig, default_soc, llama32_3b};
use agent_xpu::coordinator::AgentXpuEngine;
use agent_xpu::engine::{Engine, EngineClock, EngineEvent, registry};
use agent_xpu::workload::{FlowBinding, Priority, Request};

fn geo() -> ModelGeometry {
    let mut g = llama32_3b();
    g.n_layers = 3;
    g
}

fn agent() -> AgentXpuEngine {
    AgentXpuEngine::synthetic(geo(), default_soc(), SchedulerConfig::default())
}

fn req(id: u64, prio: Priority, arrival: f64, plen: usize, out: usize) -> Request {
    Request {
        id,
        priority: prio,
        arrival_us: arrival,
        prompt: vec![1; plen],
        max_new_tokens: out,
        profile: "core".into(),
        flow: None,
    }
}

fn flow_turns(flow_id: u64, first_id: u64) -> Vec<Request> {
    let (p0, out, delta) = (80usize, 4usize, 30usize);
    let mut turns = vec![];
    let mut prompt = vec![1i32; p0];
    for k in 0..3usize {
        if k > 0 {
            let ds = prompt.len() + out;
            prompt = vec![2; ds];
            prompt.extend(vec![1; delta]);
        }
        turns.push(Request {
            id: first_id + k as u64,
            priority: Priority::Reactive,
            arrival_us: 0.0,
            prompt: prompt.clone(),
            max_new_tokens: out,
            profile: "flow".into(),
            flow: Some(FlowBinding::linear(
                flow_id,
                k,
                3,
                if k == 0 { 0.0 } else { 10_000.0 },
                if k == 0 { 0 } else { prompt.len() - delta },
            )),
        });
    }
    turns
}

#[test]
fn event_stream_orders_each_request_lifecycle() {
    let mut e = agent();
    e.start(EngineClock::Virtual).unwrap();
    e.submit(req(1, Priority::Reactive, 0.0, 120, 4)).unwrap();
    e.submit(req(2, Priority::Proactive, 5_000.0, 200, 3)).unwrap();
    let events = e.drain().unwrap();
    let rep = e.finish().unwrap();
    assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 2);

    for id in [1u64, 2] {
        let mine: Vec<&EngineEvent> =
            events.iter().filter(|e| e.req_id() == Some(id)).collect();
        assert!(
            matches!(mine.first().unwrap(), EngineEvent::Admitted { .. }),
            "req {id} must admit first"
        );
        assert!(
            matches!(mine.last().unwrap(), EngineEvent::TurnDone { .. }),
            "req {id} must finish last"
        );
        assert!(mine.last().unwrap().is_terminal());
        let toks: Vec<_> = mine
            .iter()
            .filter(|e| matches!(e, EngineEvent::TokenEmitted { .. }))
            .collect();
        let want = rep.reqs.iter().find(|m| m.id == id).unwrap().output_tokens;
        assert_eq!(toks.len(), want, "req {id} streams every token");
        // token ordinals count up from 1
        for (i, t) in toks.iter().enumerate() {
            match t {
                EngineEvent::TokenEmitted { n, .. } => assert_eq!(*n, i + 1),
                _ => unreachable!(),
            }
        }
        // timestamps are monotone along the lifecycle
        let times: Vec<f64> = mine
            .iter()
            .map(|e| match e {
                EngineEvent::Admitted { at_us, .. }
                | EngineEvent::TokenEmitted { at_us, .. }
                | EngineEvent::TurnDone { at_us, .. }
                | EngineEvent::Preempted { at_us, .. }
                | EngineEvent::KvEvicted { at_us, .. }
                | EngineEvent::SessionEvicted { at_us, .. }
                | EngineEvent::Rebound { at_us, .. }
                | EngineEvent::Cancelled { at_us, .. } => *at_us,
            })
            .collect();
        for w in times.windows(2) {
            assert!(w[1] >= w[0], "req {id}: event timestamps must be monotone");
        }
    }
}

#[test]
fn submissions_can_arrive_mid_run() {
    let mut e = agent();
    e.start(EngineClock::Virtual).unwrap();
    e.submit(req(1, Priority::Proactive, 0.0, 300, 6)).unwrap();
    // advance a few decision points, then feed more work online
    let mut seen = vec![];
    for _ in 0..4 {
        seen.extend(e.step().unwrap());
    }
    assert!(seen.iter().any(|ev| matches!(ev, EngineEvent::Admitted { id: 1, .. })));
    e.submit(req(2, Priority::Reactive, 0.0, 100, 3)).unwrap();
    e.drain().unwrap();
    let rep = e.finish().unwrap();
    assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 2);
}

#[test]
fn cancel_between_steps_frees_the_request_and_the_rest_completes() {
    let mut e = agent();
    e.start(EngineClock::Virtual).unwrap();
    e.submit(req(1, Priority::Proactive, 0.0, 600, 30)).unwrap();
    e.submit(req(2, Priority::Proactive, 0.0, 600, 30)).unwrap();
    // run until both are admitted and in flight
    let mut events = vec![];
    while events.iter().filter(|ev| matches!(ev, EngineEvent::Admitted { .. })).count() < 2
    {
        events.extend(e.step().unwrap());
    }
    assert!(e.cancel(2).unwrap(), "in-flight request is cancellable");
    assert!(!e.cancel(2).unwrap(), "cancel is idempotent");
    events.extend(e.drain().unwrap());
    let rep = e.finish().unwrap();
    assert_eq!(rep.cancellations, 1);
    assert!(events.iter().any(|ev| matches!(ev, EngineEvent::Cancelled { id: 2, .. })));
    let m1 = rep.reqs.iter().find(|m| m.id == 1).unwrap();
    let m2 = rep.reqs.iter().find(|m| m.id == 2).unwrap();
    assert!(m1.finished() && m1.output_tokens == 30, "survivor unaffected");
    assert!(m2.cancelled && !m2.finished());
    // no TurnDone ever follows a cancel
    assert!(!events.iter().any(|ev| matches!(ev, EngineEvent::TurnDone { id: 2, .. })));
}

#[test]
fn cancel_mid_decode_retires_at_the_iteration_boundary() {
    let mut e = agent();
    e.start(EngineClock::Virtual).unwrap();
    e.submit(req(1, Priority::Reactive, 0.0, 64, 40)).unwrap();
    // run until decode is underway (some tokens out), then cancel
    let mut events = vec![];
    while events
        .iter()
        .filter(|ev| matches!(ev, EngineEvent::TokenEmitted { id: 1, .. }))
        .count()
        < 3
    {
        events.extend(e.step().unwrap());
    }
    assert!(e.cancel(1).unwrap());
    events.extend(e.drain().unwrap());
    let rep = e.finish().unwrap();
    let m = &rep.reqs[0];
    assert!(m.cancelled && !m.finished());
    assert!(m.output_tokens < 40, "cancel stopped generation early");
    assert_eq!(rep.cancellations, 1);
}

#[test]
fn cancelling_a_held_flow_turn_kills_its_placeholder_successors() {
    let mut e = agent();
    e.start(EngineClock::Virtual).unwrap();
    for r in flow_turns(9, 20) {
        e.submit(r).unwrap();
    }
    // turn 1 (id 21) is still held behind turn 0
    assert!(e.cancel(21).unwrap());
    let events = e.drain().unwrap();
    let rep = e.finish().unwrap();
    assert!(rep.reqs.iter().find(|m| m.id == 20).unwrap().finished());
    assert!(rep.reqs.iter().find(|m| m.id == 21).unwrap().cancelled);
    assert!(
        rep.reqs.iter().find(|m| m.id == 22).unwrap().cancelled,
        "turn 2's placeholder prompt cannot exist without turn 1"
    );
    assert_eq!(rep.cancellations, 2);
    assert_eq!(
        events.iter().filter(|ev| matches!(ev, EngineEvent::TurnDone { .. })).count(),
        1
    );
}

#[test]
fn every_registered_policy_supports_cancel_through_the_same_api() {
    for policy in registry::names() {
        let mut e =
            registry::build(policy, geo(), default_soc(), SchedulerConfig::default())
                .unwrap();
        let name = e.name();
        e.start(EngineClock::Virtual).unwrap();
        e.submit(req(1, Priority::Proactive, 0.0, 200, 5)).unwrap();
        e.submit(req(2, Priority::Proactive, 0.0, 200, 5)).unwrap();
        assert!(e.cancel(2).unwrap(), "{name}");
        e.drain().unwrap();
        let rep = e.finish().unwrap();
        assert_eq!(rep.cancellations, 1, "{name}");
        assert!(rep.reqs.iter().find(|m| m.id == 1).unwrap().finished(), "{name}");
        assert!(rep.reqs.iter().find(|m| m.id == 2).unwrap().cancelled, "{name}");
    }
}

#[test]
fn wall_clock_runs_serve_the_same_policy_with_measured_time() {
    let mut e = agent();
    e.start(EngineClock::wall()).unwrap();
    e.submit(req(1, Priority::Reactive, 0.0, 120, 4)).unwrap();
    e.submit(req(2, Priority::Proactive, 0.0, 200, 3)).unwrap();
    let events = e.drain().unwrap();
    assert!(!e.has_work(), "idle after drain");
    let rep = e.finish().unwrap();
    assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 2);
    for m in &rep.reqs {
        // wall timestamps: measured, ordered, non-negative
        assert!(m.arrival_us >= 0.0);
        assert!(m.first_token_us.unwrap() >= m.arrival_us);
        assert!(m.done_us.unwrap() >= m.first_token_us.unwrap());
    }
    assert!(rep.makespan_us >= 0.0);
    assert_eq!(
        events.iter().filter(|ev| matches!(ev, EngineEvent::TurnDone { .. })).count(),
        2
    );
}

#[test]
fn wall_clock_session_flows_reuse_kv_across_online_turns() {
    // the serving pattern: a continuation turn submitted only after its
    // predecessor completed, carrying the real conversation
    let mut e = agent();
    e.start(EngineClock::wall()).unwrap();
    let p1: Vec<i32> = vec![5; 60];
    e.submit(Request {
        id: 1,
        priority: Priority::Reactive,
        arrival_us: 0.0,
        prompt: p1.clone(),
        max_new_tokens: 4,
        profile: "sess".into(),
        flow: Some(FlowBinding::linear(7, 0, usize::MAX, 0.0, 0)),
    })
    .unwrap();
    let events = e.drain().unwrap();
    let toks: Vec<i32> = events
        .iter()
        .filter_map(|ev| match ev {
            EngineEvent::TokenEmitted { id: 1, token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    assert_eq!(toks.len(), 4);
    // turn 2 extends the actual conversation
    let mut p2 = p1;
    p2.extend(&toks);
    p2.extend(vec![6; 12]);
    e.submit(Request {
        id: 2,
        priority: Priority::Reactive,
        arrival_us: 0.0,
        prompt: p2,
        max_new_tokens: 3,
        profile: "sess".into(),
        flow: Some(FlowBinding::linear(7, 1, usize::MAX, 0.0, 0)),
    })
    .unwrap();
    let events2 = e.drain().unwrap();
    let done2 = events2
        .iter()
        .find_map(|ev| match ev {
            EngineEvent::TurnDone { id: 2, cached_prefix, .. } => Some(*cached_prefix),
            _ => None,
        })
        .unwrap();
    // retained KV covers the 60-token prompt + 3 of the 4 reply tokens
    assert_eq!(done2, 63, "online continuation must reuse the session KV");
}

/// Satellite audit (wall-clock held-turn release): a flow successor's
/// `arrival_us` is re-stamped to predecessor completion + think-time.
/// Under `EngineClock::Wall` both the completion stamp and the release
/// must be *wall* µs — a virtual-SoC stamp would land in the past
/// (virtual time is far smaller than wall time here) and skew serving
/// TTFT — and the run must keep stepping through the think-time gap
/// instead of stalling with the held turn never admitted.
#[test]
fn wall_clock_release_stamps_held_turns_in_wall_time() {
    let think = 20_000.0; // 20 ms of user think-time, in wall µs
    let mut e = agent();
    e.start(EngineClock::wall()).unwrap();
    let (p0, out, delta) = (80usize, 4usize, 30usize);
    let mut prompt = vec![1i32; p0];
    e.submit(Request {
        id: 1,
        priority: Priority::Reactive,
        arrival_us: 0.0,
        prompt: prompt.clone(),
        max_new_tokens: out,
        profile: "flow".into(),
        flow: Some(FlowBinding::linear(5, 0, 2, 0.0, 0)),
    })
    .unwrap();
    let ds = prompt.len() + out;
    prompt = vec![2; ds]; // placeholder — the driver stitches
    prompt.extend(vec![1; delta]);
    e.submit(Request {
        id: 2,
        priority: Priority::Reactive,
        arrival_us: 0.0,
        prompt,
        max_new_tokens: out,
        profile: "flow".into(),
        flow: Some(FlowBinding::linear(5, 1, 2, think, ds)),
    })
    .unwrap();
    // drain must cross the wall think-time gap on its own (the
    // regression: the driver stalled on future wall arrivals and
    // finish() then failed with an unfinished held turn)
    e.drain().unwrap();
    let rep = e.finish().unwrap();
    assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 2);
    let t0 = rep.reqs.iter().find(|m| m.id == 1).unwrap();
    let t1 = rep.reqs.iter().find(|m| m.id == 2).unwrap();
    // released exactly one think-time after the predecessor, in wall µs
    assert!(
        t1.arrival_us >= t0.done_us.unwrap() + think - 1e-6,
        "turn 1 released at {} before turn 0 done {} + think",
        t1.arrival_us,
        t0.done_us.unwrap()
    );
    // sanity ceiling: the release stamp is wall-domain (a virtual-µs
    // stamp would be orders of magnitude smaller than the wall clock);
    // generous bound since wall tests share noisy CI machines
    assert!(
        t1.arrival_us <= t0.done_us.unwrap() + think + 5e6,
        "turn 1 release {} implausibly late",
        t1.arrival_us
    );
    assert!(t1.first_token_us.unwrap() >= t1.arrival_us);
}

/// Fan-out/join DAG through the streaming core: branches submitted up
/// front release together after the root; the join waits for both.
#[test]
fn dag_fan_out_join_through_the_core_api() {
    use agent_xpu::workload::NodeKind;
    let mut e = agent();
    e.start(EngineClock::Virtual).unwrap();
    let mk = |id: u64, idx: usize, plen: usize, ds: usize, deps: Vec<usize>| {
        let mut prompt = vec![9i32; ds];
        prompt.extend(vec![(3 + idx) as i32; plen - ds]);
        Request {
            id,
            priority: Priority::Proactive,
            arrival_us: 0.0,
            prompt,
            max_new_tokens: 4,
            profile: "dag".into(),
            flow: Some(FlowBinding {
                flow_id: 9,
                turn_idx: idx,
                total_turns: 4,
                think_time_us: 0.0,
                delta_start: ds,
                deps,
                node: NodeKind::Llm,
                crit_path: 1,
            }),
        }
    };
    // 0 → {1, 2} → 3 (context 40+4; deltas 10/12; join delta 8)
    e.submit(mk(1, 0, 40, 0, vec![])).unwrap();
    e.submit(mk(2, 1, 54, 44, vec![0])).unwrap();
    e.submit(mk(3, 2, 56, 44, vec![0])).unwrap();
    e.submit(mk(4, 3, 82, 74, vec![1, 2])).unwrap();
    e.drain().unwrap();
    let rep = e.finish().unwrap();
    assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 4);
    let get = |id: u64| rep.reqs.iter().find(|m| m.id == id).unwrap();
    let (root, b1, b2, join) = (get(1), get(2), get(3), get(4));
    for b in [b1, b2] {
        assert!(b.arrival_us >= root.done_us.unwrap() - 1e-6);
    }
    let last = b1.done_us.unwrap().max(b2.done_us.unwrap());
    assert!(join.arrival_us >= last - 1e-6, "join held until both branches done");
    assert!(join.first_token_us.unwrap() > last);
    // the flow rollup sees one finished DAG with a critical-path bound
    let flows = rep.flows();
    assert_eq!(flows.len(), 1);
    assert!(flows[0].finished);
    assert!(flows[0].e2e_us.unwrap() + 1e-6 >= flows[0].critical_path_us.unwrap());
}
