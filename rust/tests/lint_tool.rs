//! Fixture-driven tests for the `agent-xpu lint` pass (DESIGN.md §10):
//! every rule fires exactly on its bad fixture and stays silent on its
//! good twin, the allow and registry machinery resolve over a mini
//! tree, and the shipped tree itself scans clean under the checked-in
//! `lint.json`.

use std::path::Path;

use agent_xpu::lint::{self, LintConfig};
use agent_xpu::util::json::Json;

fn fixture(name: &str) -> String {
    let path = Path::new("tests/lint_fixtures").join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Rule names firing on fixture `name` scanned as if it lived at `rel`.
fn rules_at(rel: &str, name: &str) -> Vec<String> {
    let cfg = LintConfig::default_config();
    let scan = lint::scan_source(rel, &fixture(name), &cfg);
    scan.diags.iter().map(|d| d.rule.to_string()).collect()
}

#[test]
fn wall_clock_fires_in_core_and_nowhere_else() {
    // one hit: the wall read in real code; the one in the test module
    // is exempt
    assert_eq!(rules_at("src/engine/fx.rs", "wall_clock_bad.rs"), ["no-wall-clock"]);
    assert!(rules_at("src/engine/fx.rs", "wall_clock_good.rs").is_empty());
    // outside the deterministic core the rule does not apply
    assert!(rules_at("src/server/fx.rs", "wall_clock_bad.rs").is_empty());
}

#[test]
fn unordered_iteration_fires_on_order_sensitive_walks_only() {
    let bad = rules_at("src/engine/fx.rs", "unordered_bad.rs");
    assert_eq!(bad, ["no-unordered-iteration", "no-unordered-iteration"]);
    // order-free reductions (sum / any / count) pass the chain analysis
    assert!(rules_at("src/engine/fx.rs", "unordered_good.rs").is_empty());
    assert!(rules_at("src/server/fx.rs", "unordered_bad.rs").is_empty());
}

#[test]
fn lock_hygiene_fires_everywhere_including_tests() {
    assert_eq!(rules_at("tests/fx.rs", "lock_bad.rs"), ["lock-hygiene"]);
    assert_eq!(rules_at("src/server/fx.rs", "lock_bad.rs"), ["lock-hygiene"]);
    assert!(rules_at("src/server/fx.rs", "lock_good.rs").is_empty());
}

#[test]
fn panic_free_fires_on_all_four_forms_in_hot_path_files() {
    let bad = rules_at("src/coordinator/dispatch.rs", "panic_bad.rs");
    // unwrap, expect, panic!, todo! — the `#[test]` fn is exempt
    assert_eq!(bad.len(), 4);
    assert!(bad.iter().all(|r| r == "panic-free-hot-path"));
    assert!(rules_at("src/coordinator/dispatch.rs", "panic_good.rs").is_empty());
    // files off the hot path are not under the rule
    assert!(rules_at("src/engine/core_api.rs", "panic_bad.rs").is_empty());
}

#[test]
fn safety_comments_fire_on_bare_unsafe_only() {
    let bad = rules_at("src/runtime/fx.rs", "safety_bad.rs");
    assert_eq!(bad, ["safety-comments", "safety-comments"]);
    // justified blocks, trailing justifications, and a Send+Sync pair
    // sharing one comment all pass
    assert!(rules_at("src/runtime/fx.rs", "safety_good.rs").is_empty());
}

#[test]
fn json_hygiene_fires_in_serializer_paths_only() {
    assert_eq!(rules_at("src/metrics/fx.rs", "json_bad.rs"), ["json-hygiene"]);
    assert!(rules_at("src/metrics/fx.rs", "json_good.rs").is_empty());
    assert!(rules_at("src/server/fx.rs", "json_bad.rs").is_empty());
}

#[test]
fn registry_coverage_and_allows_resolve_over_the_mini_tree() {
    let cfg = LintConfig::default_config();
    let root = Path::new("tests/lint_fixtures/registry_tree");
    let rep = lint::run(root, &["src".to_string()], &cfg).unwrap();

    // exactly the unregistered pair is flagged; the registered pair and
    // the test-module double are not
    let mut uncovered = Vec::new();
    for v in &rep.violations {
        if v.rule == "registry-coverage" {
            uncovered.push(v.msg.clone());
        }
    }
    assert_eq!(uncovered.len(), 2, "registry violations: {uncovered:?}");
    assert!(uncovered.iter().any(|m| m.contains("BadPolicy")));
    assert!(uncovered.iter().any(|m| m.contains("BadRouter")));
    for v in &rep.violations {
        assert!(!v.msg.contains("GoodPolicy"), "{}", v.msg);
        assert!(!v.msg.contains("GoodRouter"), "{}", v.msg);
        assert!(!v.msg.contains("TestOnlyPolicy"), "{}", v.msg);
    }

    // a reasonless allow is rejected and its site stays a violation
    assert!(rep.violations.iter().any(|v| v.rule == "lint-allow"));
    assert!(rep
        .violations
        .iter()
        .any(|v| v.file.ends_with("allows.rs") && v.rule == "no-wall-clock"));

    // the proper allow suppressed its site and is recorded with its
    // reason; the stale allow surfaces as unused, not fatal
    assert_eq!(rep.allowed.len(), 1);
    assert!(rep.allowed[0].reason.contains("sanctioned"));
    assert_eq!(rep.unused_allows.len(), 1);
}

#[test]
fn the_shipped_tree_is_lint_clean() {
    let rep = lint::run_default(Path::new(".")).unwrap();
    let mut lines = Vec::new();
    for v in &rep.violations {
        lines.push(format!("{}:{} {} {}", v.file, v.line, v.rule, v.msg));
    }
    assert!(rep.clean(), "lint violations in the shipped tree:\n{}", lines.join("\n"));
    assert!(rep.files_scanned > 50, "walked only {} files", rep.files_scanned);
    // the allowlist is real (wall-clock epoch, driver invariants, …),
    // every entry carries a reason, and none are stale
    assert!(rep.allowed.len() >= 20, "only {} allows recorded", rep.allowed.len());
    for a in &rep.allowed {
        assert!(!a.reason.is_empty(), "{}:{} allow without reason", a.file, a.line);
    }
    assert!(
        rep.unused_allows.is_empty(),
        "stale allows: {:?}",
        rep.unused_allows
            .iter()
            .map(|a| format!("{}:{}", a.file, a.line))
            .collect::<Vec<_>>()
    );
}

#[test]
fn the_json_report_is_strict_rfc8259() {
    let rep = lint::run_default(Path::new(".")).unwrap();
    let text = rep.to_json().to_string();
    let doc = Json::parse(&text).unwrap();
    assert_eq!(doc.opt("violation_count").unwrap().as_i64().unwrap(), 0);
    assert!(doc.opt("allow_count").unwrap().as_i64().unwrap() > 0);
    assert_eq!(doc.opt("rules").unwrap().as_arr().unwrap().len(), 7);
    assert_eq!(
        doc.opt("allow_count").unwrap().as_i64().unwrap() as usize,
        doc.opt("allowed").unwrap().as_arr().unwrap().len(),
    );
}

#[test]
fn the_cli_gate_emits_the_report_and_exits_zero() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_agent-xpu"))
        .args(["lint", "--json"])
        .output()
        .expect("spawning agent-xpu");
    assert!(
        out.status.success(),
        "lint CLI failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let doc = Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(doc.opt("violation_count").unwrap().as_i64().unwrap(), 0);
    assert!(doc.opt("allow_count").unwrap().as_i64().unwrap() > 0);
}
