"""Pallas GQA attention kernels — the paper's MHA hot-spot (L1).

The paper's NPU cannot run dynamic-shape attention, so MHA lands on the
iGPU; here we express both the chunked-prefill and the batched-decode
attention as Pallas kernels with *static* shapes plus a scalar position
input — exactly the static-kernel + scalar-dynamism contract that makes a
kernel precompilable for an NPU-class accelerator (DESIGN.md
§Hardware-Adaptation).

TPU adaptation of the paper's insight:
  - the KV cache is tiled per KV-head into VMEM-sized blocks via BlockSpec
    (the paper used fixed-size MAC-array tiles);
  - the grid iterates over query heads so each program's working set
    (q-block [c, hd] + kv-block [s, hd] + scores [c, s]) fits VMEM;
  - ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
    Mosaic custom-calls, so kernels lower to plain HLO (see
    /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _prefill_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, scale):
    """One KV head vs. its whole query-head *group* (GQA reuse: the KV
    block is loaded into local memory once and serves every query head
    that shares it — the grid trips scale with kv_heads, not q_heads).

    Block shapes: pos [1], q [c, G, hd], k/v [s, 1, hd], o [c, G, hd].
    """
    c, groups, hd = q_ref.shape
    q = q_ref[...].reshape(c * groups, hd)  # token-major rows
    k = k_ref[:, 0, :]  # [s, hd]
    v = v_ref[:, 0, :]
    s = k.shape[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [c*G, s]
    pos = pos_ref[0]
    j = jax.lax.broadcasted_iota(jnp.int32, (c * groups, s), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (c * groups, s), 0)
    i = pos + row // groups  # query token index of each row
    scores = jnp.where(j <= i, scores, NEG_INF)
    # Numerically-stable softmax in-kernel (flash-style single pass over
    # the statically-sized cache block).
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.dot(probs, v, preferred_element_type=jnp.float32)
    o_ref[...] = o.reshape(c, groups, hd)


def gqa_attention(
    q: jax.Array,  # [c, qh, hd]
    k_cache: jax.Array,  # [s, kh, hd]
    v_cache: jax.Array,  # [s, kh, hd]
    pos: jax.Array,  # i32[1]
) -> jax.Array:
    """Chunked-prefill causal GQA attention against a static-max KV cache."""
    c, qh, hd = q.shape
    s, kh, _ = k_cache.shape
    groups = qh // kh
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(_prefill_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(kh,),
        in_specs=[
            pl.BlockSpec((1,), lambda g: (0,)),  # pos: broadcast scalar
            pl.BlockSpec((c, groups, hd), lambda g: (0, g, 0)),  # q group g
            pl.BlockSpec((s, 1, hd), lambda g: (0, g, 0)),  # k head g
            pl.BlockSpec((s, 1, hd), lambda g: (0, g, 0)),  # v head g
        ],
        out_specs=pl.BlockSpec((c, groups, hd), lambda g: (0, g, 0)),
        out_shape=jax.ShapeDtypeStruct((c, qh, hd), jnp.float32),
        interpret=True,
    )(pos, q, k_cache, v_cache)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, scale):
    """One (sequence, kv-head) pair of a batched decode step; the KV
    block serves the kv-head's whole query group (GQA reuse).

    Block shapes: pos [1], q [1, G, hd], k/v [1, s, 1, hd], o [1, G, hd].
    """
    _, groups, hd = q_ref.shape
    q = q_ref[0]  # [G, hd]
    k = k_ref[0, :, 0, :]  # [s, hd]
    v = v_ref[0, :, 0, :]
    s = k.shape[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, s]
    pos = pos_ref[0]
    j = jax.lax.broadcasted_iota(jnp.int32, (groups, s), 1)
    scores = jnp.where(j <= pos, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(probs, v, preferred_element_type=jnp.float32)


def gqa_decode_attention(
    q: jax.Array,  # [b, qh, hd]
    k_cache: jax.Array,  # [b, s, kh, hd]
    v_cache: jax.Array,  # [b, s, kh, hd]
    pos: jax.Array,  # i32[b]
) -> jax.Array:
    """Batched single-token GQA attention (decode step)."""
    b, qh, hd = q.shape
    s = k_cache.shape[1]
    kh = k_cache.shape[2]
    groups = qh // kh
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(_decode_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, kh),
        in_specs=[
            pl.BlockSpec((1,), lambda i, g: (i,)),  # pos[i]
            pl.BlockSpec((1, groups, hd), lambda i, g: (i, g, 0)),
            pl.BlockSpec((1, s, 1, hd), lambda i, g: (i, 0, g, 0)),
            pl.BlockSpec((1, s, 1, hd), lambda i, g: (i, 0, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, groups, hd), lambda i, g: (i, g, 0)),
        out_shape=jax.ShapeDtypeStruct((b, qh, hd), jnp.float32),
        interpret=True,
    )(pos, q, k_cache, v_cache)
