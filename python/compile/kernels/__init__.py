"""L1 Pallas kernels for the paper's compute hot-spots, plus the pure-jnp
oracle (`ref`) they are verified against."""
