"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an exact counterpart here, written
with nothing but ``jax.numpy``.  ``python/tests/test_kernel.py`` sweeps
shapes/seeds with hypothesis and asserts allclose between the two.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def linear_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain matmul: x[n, din] @ w[din, dout]."""
    return x @ w


def swiglu_ref(x: jax.Array, wg: jax.Array, wu: jax.Array) -> jax.Array:
    """SwiGLU gate: silu(x @ wg) * (x @ wu)."""
    return jax.nn.silu(x @ wg) * (x @ wu)


def rope_ref(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding.

    x: [n, heads, head_dim]; positions: i32[n].  Rotates pairs
    (x[..., :hd/2], x[..., hd/2:]) — the "split halves" Llama convention.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [n, half]
    cos = jnp.cos(angles)[:, None, :]  # [n, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def gqa_attention_ref(
    q: jax.Array,  # [c, qh, hd] — chunk of query tokens at positions pos..pos+c
    k_cache: jax.Array,  # [s, kh, hd] — KV cache, valid at 0..pos+c
    v_cache: jax.Array,  # [s, kh, hd]
    pos: jax.Array,  # i32[1] — number of cached tokens before this chunk
) -> jax.Array:
    """Causal GQA attention of a prefill chunk against a static-max cache.

    Query i (global position pos+i) attends to cache slots j <= pos+i.
    Slots beyond pos+c may hold garbage (padding) — they are masked.
    """
    c, qh, hd = q.shape
    s, kh, _ = k_cache.shape
    groups = qh // kh
    scale = 1.0 / jnp.sqrt(jnp.array(hd, jnp.float32))
    k = jnp.repeat(k_cache, groups, axis=1)  # [s, qh, hd]
    v = jnp.repeat(v_cache, groups, axis=1)
    scores = jnp.einsum("cqd,sqd->qcs", q, k) * scale  # [qh, c, s]
    j = jnp.arange(s)[None, None, :]
    i = pos[0] + jnp.arange(c)[None, :, None]
    scores = jnp.where(j <= i, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("qcs,sqd->cqd", probs, v)


def gqa_decode_attention_ref(
    q: jax.Array,  # [b, qh, hd] — one new token per sequence
    k_cache: jax.Array,  # [b, s, kh, hd]
    v_cache: jax.Array,  # [b, s, kh, hd]
    pos: jax.Array,  # i32[b] — position of the new token for each sequence
) -> jax.Array:
    """Batched single-token (decode) GQA attention; attends j <= pos[b]."""
    b, qh, hd = q.shape
    s = k_cache.shape[1]
    kh = k_cache.shape[2]
    groups = qh // kh
    scale = 1.0 / jnp.sqrt(jnp.array(hd, jnp.float32))
    k = jnp.repeat(k_cache, groups, axis=2)  # [b, s, qh, hd]
    v = jnp.repeat(v_cache, groups, axis=2)
    scores = jnp.einsum("bqd,bsqd->bqs", q, k) * scale
    j = jnp.arange(s)[None, None, :]
    mask = j <= pos[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqs,bsqd->bqd", probs, v)
