"""Pallas fused-linear kernels — the paper's NPU chunked-GEMM hot-spot (L1).

The paper precompiles static chunked GEMM kernels for the NPU's MAC array
(§5.2 "elastic chunked kernel").  The TPU analogue tiles the output
dimension into VMEM-resident blocks with BlockSpec; the sequence-chunk
dimension (n) is the static chunk size baked into each artifact variant.

``fused_swiglu`` additionally fuses the SwiGLU gate (silu(x@wg) * (x@wu))
into one kernel — the paper's op-group fusion of linear + adjacent
nonlinear ops to maximize local-memory reuse (§5.2 Compute-Communicate
Balance).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Upper bound on the output-tile width.  Large tiles keep the grid trip
#: count low (one VMEM-resident block per program; fewer HBM round
#: trips on TPU, fewer loop iterations under interpret=True).  512 f32
#: lanes x a few hundred rows stays comfortably inside a 16 MB VMEM
#: budget alongside the input block (DESIGN.md SHardware-Adaptation).
_MAX_TILE = 512


def _pick_tile(dout: int) -> int:
    """Largest divisor of dout that is <= _MAX_TILE."""
    best = 1
    for t in range(1, min(dout, _MAX_TILE) + 1):
        if dout % t == 0:
            best = t
    return best


def _linear_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """Tiled matmul: x[n, din] @ w[din, dout] with the output dimension
    split into VMEM-sized column blocks."""
    n, din = x.shape
    dout = w.shape[1]
    bn = _pick_tile(dout)
    return pl.pallas_call(
        _linear_kernel,
        grid=(dout // bn,),
        in_specs=[
            pl.BlockSpec((n, din), lambda j: (0, 0)),
            pl.BlockSpec((din, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, dout), jnp.float32),
        interpret=True,
    )(x, w)


def _swiglu_kernel(x_ref, wg_ref, wu_ref, o_ref):
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = g * jax.lax.logistic(g) * u  # silu(g) * u


def fused_swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array) -> jax.Array:
    """Fused SwiGLU: silu(x @ wg) * (x @ wu), tiled over the ffn dim."""
    n, din = x.shape
    dff = wg.shape[1]
    bn = _pick_tile(dff)
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(dff // bn,),
        in_specs=[
            pl.BlockSpec((n, din), lambda j: (0, 0)),
            pl.BlockSpec((din, bn), lambda j: (0, j)),
            pl.BlockSpec((din, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, dff), jnp.float32),
        interpret=True,
    )(x, wg, wu)
