"""L2: Llama-architecture model in JAX, built on the L1 Pallas kernels.

Each function below is one HEG kernel — the unit the Rust coordinator
schedules, preempts, and backfills.  The same ``layer_prefill`` /
``layer_decode`` HLO module is reused for every transformer layer (the
weights are arguments, not constants), which is what makes the artifact
set small and the NPU-style precompilation practical.

KV-cache contract (mirrors the paper's unified-memory design):
  - the cache is a static-max tensor [max_seq, kv_heads, head_dim];
  - ``pos`` counts valid tokens already cached; a prefill chunk writes its
    K/V at slots pos..pos+c (a padded margin chunk writes garbage beyond
    the true length — harmless, because causal masks never look past the
    current position, and the next decode step overwrites slot pos);
  - functions return the updated cache; the Rust side owns residency.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.attention import gqa_attention, gqa_decode_attention
from .kernels.linear import linear, fused_swiglu
from .kernels.ref import rmsnorm_ref as rmsnorm, rope_ref as rope

#: Per-layer weight tensor names, in artifact argument order.
LAYER_WEIGHTS = (
    "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "wg", "wu", "wd",
)


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Seeded-random weights (DESIGN.md §1: no offline checkpoints;
    scheduling behaviour is weight-value-independent)."""
    key = jax.random.key(seed)
    d, f, kvd = cfg.d_model, cfg.d_ffn, cfg.n_kv_heads * cfg.head_dim
    params = {}
    key, k = jax.random.split(key)
    params["emb"] = jax.random.normal(k, (cfg.vocab, d), jnp.float32) * 0.02
    params["final_norm"] = jnp.ones((d,), jnp.float32)
    for i in range(cfg.n_layers):
        shapes = {
            "attn_norm": (d,), "mlp_norm": (d,),
            "wq": (d, d), "wk": (d, kvd), "wv": (d, kvd), "wo": (d, d),
            "wg": (d, f), "wu": (d, f), "wd": (f, d),
        }
        for name, shape in shapes.items():
            key, k = jax.random.split(key)
            if name.endswith("norm"):
                params[f"l{i}.{name}"] = jnp.ones(shape, jnp.float32)
            else:
                scale = 1.0 / (shape[0] ** 0.5)
                params[f"l{i}.{name}"] = (
                    jax.random.normal(k, shape, jnp.float32) * scale
                )
    return params


def embed(tokens: jax.Array, emb: jax.Array) -> jax.Array:
    """Token embedding lookup: i32[n] -> f32[n, d]."""
    return jnp.take(emb, tokens, axis=0)


def _make_layer_core(cfg: ModelConfig):
    """Shared attention+MLP body used by both prefill and decode."""

    def attn_block(x, k_cache, v_cache, pos_vec, positions,
                   attn_norm, wq, wk, wv, wo, decode: bool):
        n = x.shape[0]
        h = rmsnorm(x, attn_norm)
        q = linear(h, wq).reshape(n, cfg.n_q_heads, cfg.head_dim)
        k = linear(h, wk).reshape(n, cfg.n_kv_heads, cfg.head_dim)
        v = linear(h, wv).reshape(n, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if decode:
            # Scatter each sequence's new K/V at its own position.
            def upd(cache, new, p):
                return jax.lax.dynamic_update_slice(cache, new[None], (p, 0, 0))
            k_cache = jax.vmap(upd)(k_cache, k, pos_vec)
            v_cache = jax.vmap(upd)(v_cache, v, pos_vec)
            o = gqa_decode_attention(q, k_cache, v_cache, pos_vec)
        else:
            k_cache = jax.lax.dynamic_update_slice(k_cache, k, (pos_vec[0], 0, 0))
            v_cache = jax.lax.dynamic_update_slice(v_cache, v, (pos_vec[0], 0, 0))
            o = gqa_attention(q, k_cache, v_cache, pos_vec)
        o = linear(o.reshape(n, cfg.d_model), wo)
        return x + o, k_cache, v_cache

    def mlp_block(x, mlp_norm, wg, wu, wd):
        h = rmsnorm(x, mlp_norm)
        return x + linear(fused_swiglu(h, wg, wu), wd)

    return attn_block, mlp_block


def make_layer_prefill(cfg: ModelConfig):
    """Prefill chunk through one transformer layer.

    Signature (static chunk size c, the elastic-chunked-kernel contract):
      (x[c,d], k_cache[s,kh,hd], v_cache[s,kh,hd], pos i32[1],
       attn_norm, wq, wk, wv, wo, mlp_norm, wg, wu, wd)
      -> (y[c,d], k_cache', v_cache')
    """
    attn_block, mlp_block = _make_layer_core(cfg)

    def layer_prefill(x, k_cache, v_cache, pos,
                      attn_norm, wq, wk, wv, wo, mlp_norm, wg, wu, wd):
        c = x.shape[0]
        positions = pos[0] + jnp.arange(c, dtype=jnp.int32)
        x, k_cache, v_cache = attn_block(
            x, k_cache, v_cache, pos, positions,
            attn_norm, wq, wk, wv, wo, decode=False)
        x = mlp_block(x, mlp_norm, wg, wu, wd)
        return x, k_cache, v_cache

    return layer_prefill


def make_layer_decode(cfg: ModelConfig):
    """Batched decode step through one transformer layer.

    Signature (static batch size b):
      (x[b,d], k_cache[b,s,kh,hd], v_cache[b,s,kh,hd], pos i32[b],
       attn_norm, wq, wk, wv, wo, mlp_norm, wg, wu, wd)
      -> (y[b,d], k_cache', v_cache')
    """
    attn_block, mlp_block = _make_layer_core(cfg)

    def layer_decode(x, k_cache, v_cache, pos,
                     attn_norm, wq, wk, wv, wo, mlp_norm, wg, wu, wd):
        x, k_cache, v_cache = attn_block(
            x, k_cache, v_cache, pos, pos,
            attn_norm, wq, wk, wv, wo, decode=True)
        x = mlp_block(x, mlp_norm, wg, wu, wd)
        return x, k_cache, v_cache

    return layer_decode


def head(x: jax.Array, final_norm: jax.Array, emb: jax.Array) -> jax.Array:
    """Greedy sampling head: f32[b, d] -> next-token i32[b].

    Tied embeddings (logits = norm(x) @ emb.T); greedy argmax keeps the
    reproduction deterministic end-to-end.
    """
    h = rmsnorm(x, final_norm)
    logits = h @ emb.T
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Pure-python full pipelines (test oracles; never lowered).
# ---------------------------------------------------------------------------

def layer_params(params: dict, i: int) -> list:
    return [params[f"l{i}.{n}"] for n in LAYER_WEIGHTS]


def empty_cache(cfg: ModelConfig) -> jax.Array:
    return jnp.zeros((cfg.max_seq, cfg.n_kv_heads, cfg.head_dim), jnp.float32)


def prefill_chunked(cfg: ModelConfig, params: dict, tokens, chunk: int):
    """Chunked prefill of a whole prompt (pads the margin chunk).

    Returns (last_valid_hidden[1, d], k_caches, v_caches) — the same data
    flow the Rust coordinator drives chunk-by-chunk, kernel-by-kernel.
    """
    tokens = jnp.asarray(tokens, jnp.int32)
    n = tokens.shape[0]
    k_caches = [empty_cache(cfg) for _ in range(cfg.n_layers)]
    v_caches = [empty_cache(cfg) for _ in range(cfg.n_layers)]
    fns = [make_layer_prefill(cfg) for _ in range(cfg.n_layers)]
    last_hidden = None
    pos = 0
    while pos < n:
        m = min(chunk, n - pos)
        chunk_tokens = jnp.zeros((chunk,), jnp.int32).at[:m].set(tokens[pos:pos + m])
        x = embed(chunk_tokens, params["emb"])
        pvec = jnp.array([pos], jnp.int32)
        for i in range(cfg.n_layers):
            x, k_caches[i], v_caches[i] = fns[i](
                x, k_caches[i], v_caches[i], pvec, *layer_params(params, i))
        last_hidden = x[m - 1:m]
        pos += m
    return last_hidden, k_caches, v_caches


def decode_steps(cfg: ModelConfig, params: dict, last_hidden, k_caches,
                 v_caches, start_pos: int, steps: int):
    """Greedy decode of `steps` tokens for a single sequence (b=1)."""
    fn = make_layer_decode(cfg)
    out_tokens = []
    x = last_hidden  # [1, d]
    k_caches = [kc[None] for kc in k_caches]  # [1, s, kh, hd]
    v_caches = [vc[None] for vc in v_caches]
    pos = start_pos
    for _ in range(steps):
        tok = head(x, params["final_norm"], params["emb"])  # i32[1]
        out_tokens.append(int(tok[0]))
        x = embed(tok, params["emb"])
        pvec = jnp.array([pos], jnp.int32)
        for i in range(cfg.n_layers):
            x, k_caches[i], v_caches[i] = fn(
                x, k_caches[i], v_caches[i], pvec, *layer_params(params, i))
        pos += 1
    return out_tokens


def full_prefill_ref(cfg: ModelConfig, params: dict, tokens):
    """Un-chunked oracle: whole prompt as one chunk of its exact length,
    using only ref ops via the same layer functions (chunk == len)."""
    tokens = jnp.asarray(tokens, jnp.int32)
    n = tokens.shape[0]
    x = embed(tokens, params["emb"])
    k_caches = [empty_cache(cfg) for _ in range(cfg.n_layers)]
    v_caches = [empty_cache(cfg) for _ in range(cfg.n_layers)]
    fn = make_layer_prefill(cfg)
    pvec = jnp.array([0], jnp.int32)
    for i in range(cfg.n_layers):
        x, k_caches[i], v_caches[i] = fn(
            x, k_caches[i], v_caches[i], pvec, *layer_params(params, i))
    return x[n - 1:n], k_caches, v_caches
