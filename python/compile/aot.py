"""AOT lowering pipeline: JAX model -> HLO *text* artifacts + manifest.

This is the only place Python touches the serving stack.  ``make
artifacts`` runs it once per model config; the Rust coordinator then loads
``artifacts/<config>/manifest.json`` and the referenced ``*.hlo.txt``
modules via the xla crate's PJRT CPU client and never calls back into
Python.

Interchange is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Per-config outputs (``artifacts/<config>/``):
  - ``<kernel>.hlo.txt``      one module per (phase, chunk/batch) variant
  - ``manifest.json``         geometry + per-artifact arg/output specs
  - ``weights.npz``           seeded-random parameters (uncompressed zip,
                              read by ``xla::Literal::read_npz`` in Rust)
  - ``golden.json``           prompt -> greedy tokens, for the Rust
                              integration test to diff against
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, ModelConfig
from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side can uniformly unwrap a tuple root)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _arg_json(specs):
    return [{"dtype": str(s.dtype), "shape": list(s.shape)} for s in specs]


def kernel_variants(cfg: ModelConfig):
    """Yield (name, fn, arg_specs, meta) for every artifact of a config."""
    d, s = cfg.d_model, cfg.max_seq
    kh, hd, f, v = cfg.n_kv_heads, cfg.head_dim, cfg.d_ffn, cfg.vocab
    wspecs = [
        _spec((d,)), _spec((d, d)), _spec((d, kh * hd)), _spec((d, kh * hd)),
        _spec((d, d)), _spec((d,)), _spec((d, f)), _spec((d, f)), _spec((f, d)),
    ]

    sizes = sorted(set(cfg.chunk_sizes) | set(cfg.batch_sizes))
    for n in sizes:
        yield (
            f"embed_n{n}", M.embed,
            [_spec((n,), jnp.int32), _spec((v, d))],
            {"kind": "embed", "n": n},
        )
    for c in cfg.chunk_sizes:
        yield (
            f"layer_prefill_c{c}", M.make_layer_prefill(cfg),
            [_spec((c, d)), _spec((s, kh, hd)), _spec((s, kh, hd)),
             _spec((1,), jnp.int32), *wspecs],
            {"kind": "layer_prefill", "n": c},
        )
    for b in cfg.batch_sizes:
        yield (
            f"layer_decode_b{b}", M.make_layer_decode(cfg),
            [_spec((b, d)), _spec((b, s, kh, hd)), _spec((b, s, kh, hd)),
             _spec((b,), jnp.int32), *wspecs],
            {"kind": "layer_decode", "n": b},
        )
        yield (
            f"head_b{b}", M.head,
            [_spec((b, d)), _spec((d,)), _spec((v, d))],
            {"kind": "head", "n": b},
        )


def export_weights(cfg: ModelConfig, out_dir: Path, seed: int) -> dict:
    params = M.init_params(cfg, seed=seed)
    arrays = {k: np.asarray(v) for k, v in params.items()}
    # np.savez writes ZIP_STORED members — exactly what the xla crate's
    # npz reader expects.
    np.savez(out_dir / "weights.npz", **arrays)
    return params


def export_golden(cfg: ModelConfig, params: dict, out_dir: Path):
    """Golden trajectory the Rust integration test replays byte-for-byte."""
    rng = np.random.default_rng(42)
    cases = []
    for prompt_len, gen in [(21, 8), (cfg.chunk_sizes[0], 4), (5, 6)]:
        toks = [int(t) for t in rng.integers(0, cfg.vocab, prompt_len)]
        chunk = cfg.chunk_sizes[0]
        h, kc, vc = M.prefill_chunked(cfg, params, toks, chunk=chunk)
        out = M.decode_steps(cfg, params, h, kc, vc, start_pos=prompt_len,
                             steps=gen)
        cases.append({
            "prompt": toks, "chunk": chunk, "generated": out,
            "last_hidden_l2": float(jnp.linalg.norm(h)),
        })
    (out_dir / "golden.json").write_text(json.dumps(cases, indent=1))


def build_config(cfg: ModelConfig, root: Path, seed: int, golden: bool):
    out_dir = root / cfg.name
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {
        "config": cfg.to_dict(),
        "seed": seed,
        "weights": "weights.npz",
        "layer_weight_names": list(M.LAYER_WEIGHTS),
        "artifacts": {},
    }
    t0 = time.time()
    for name, fn, specs, meta in kernel_variants(cfg):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        (out_dir / f"{name}.hlo.txt").write_text(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": _arg_json(specs),
            **meta,
        }
        print(f"  {cfg.name}/{name}: {len(text) / 1024:.0f} KiB "
              f"({time.time() - t0:.1f}s elapsed)")
    params = export_weights(cfg, out_dir, seed)
    if golden:
        export_golden(cfg, params, out_dir)
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"  {cfg.name}: manifest + weights"
          + (" + golden" if golden else "") + " written")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact root")
    ap.add_argument("--configs", default="tiny,small",
                    help="comma-separated config names (or 'all')")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-golden", action="store_true",
                    help="skip golden-trajectory export (slow for 'base')")
    args = ap.parse_args()
    names = list(CONFIGS) if args.configs == "all" else args.configs.split(",")
    root = Path(args.out)
    for name in names:
        cfg = CONFIGS[name]
        print(f"building {name} ({cfg.n_params / 1e6:.1f}M params)")
        # golden replay of `base` takes minutes of CPU; tests use tiny/small
        golden = not args.no_golden and name != "base"
        build_config(cfg, root, args.seed, golden)


if __name__ == "__main__":
    main()
