"""Model geometry presets shared by the JAX model (L2), the AOT lowering
pipeline, and (via manifest.json) the Rust coordinator (L3).

The paper serves Llama-3.2-3B on an Intel Core Ultra SoC. We reproduce the
architecture family (RMSNorm + RoPE + GQA + SwiGLU + tied embeddings) at
three sizes:

- ``tiny``  (~1M params)  — unit tests and golden vectors; seconds to lower.
- ``small`` (~8M params)  — default artifact set for examples/benches.
- ``base``  (~82M params) — the end-to-end serving example (EXPERIMENTS.md).
"""

from dataclasses import dataclass, field, asdict
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_q_heads: int
    n_kv_heads: int
    head_dim: int
    d_ffn: int
    max_seq: int
    # Static prefill chunk sizes precompiled for the (virtual) NPU.  The
    # paper's "elastic chunked kernel": token-level op groups are chunked
    # along the sequence dimension so the NPU can use precompiled static
    # kernels (Section 5.2).
    chunk_sizes: Tuple[int, ...]
    # Decode batch sizes precompiled for the iGPU (adaptive batching, §6.3).
    batch_sizes: Tuple[int, ...]
    rope_theta: float = 10000.0

    def __post_init__(self):
        assert self.d_model == self.n_q_heads * self.head_dim, (
            f"{self.name}: d_model must equal n_q_heads*head_dim"
        )
        assert self.n_q_heads % self.n_kv_heads == 0, (
            f"{self.name}: GQA requires n_q_heads % n_kv_heads == 0"
        )
        for c in self.chunk_sizes:
            assert self.max_seq % c == 0, (
                f"{self.name}: chunk {c} must divide max_seq {self.max_seq}"
            )

    @property
    def groups(self) -> int:
        return self.n_q_heads // self.n_kv_heads

    @property
    def n_params(self) -> int:
        per_layer = (
            self.d_model * self.d_model  # wq
            + 2 * self.d_model * self.n_kv_heads * self.head_dim  # wk, wv
            + self.d_model * self.d_model  # wo
            + 3 * self.d_model * self.d_ffn  # wg, wu, wd
            + 2 * self.d_model  # norms
        )
        return self.n_layers * per_layer + self.vocab * self.d_model + self.d_model

    def to_dict(self) -> dict:
        return asdict(self)


CONFIGS = {
    "tiny": ModelConfig(
        name="tiny", vocab=512, d_model=128, n_layers=2,
        n_q_heads=4, n_kv_heads=2, head_dim=32, d_ffn=256,
        max_seq=128, chunk_sizes=(16, 32), batch_sizes=(1, 2, 4),
    ),
    "small": ModelConfig(
        name="small", vocab=2048, d_model=256, n_layers=6,
        n_q_heads=8, n_kv_heads=2, head_dim=32, d_ffn=704,
        max_seq=512, chunk_sizes=(16, 32, 64, 128), batch_sizes=(1, 2, 4, 8),
    ),
    "base": ModelConfig(
        name="base", vocab=8192, d_model=768, n_layers=12,
        n_q_heads=12, n_kv_heads=4, head_dim=64, d_ffn=2048,
        max_seq=1024, chunk_sizes=(32, 64, 128, 256), batch_sizes=(1, 2, 4, 8),
    ),
}
