"""Build-time compile path: L1 Pallas kernels, L2 JAX model, AOT lowering.
Never imported on the serving path (the Rust binary loads HLO artifacts)."""
