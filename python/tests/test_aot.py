"""AOT pipeline checks: manifest completeness, HLO-text validity, weight
export round-trip, golden-file consistency.

Runs against a session-scoped freshly-built tiny artifact tree so the
tests do not depend on `make artifacts` having run first.
"""

import json
import zipfile
from pathlib import Path

import jax
import numpy as np
import pytest

from compile.configs import CONFIGS
from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

CFG = CONFIGS["tiny"]


@pytest.fixture(scope="session")
def art_dir(tmp_path_factory):
    root = tmp_path_factory.mktemp("artifacts")
    aot.build_config(CFG, root, seed=0, golden=True)
    return root / CFG.name


@pytest.fixture(scope="session")
def manifest(art_dir):
    return json.loads((art_dir / "manifest.json").read_text())


def test_manifest_geometry(manifest):
    assert manifest["config"]["name"] == "tiny"
    assert manifest["config"]["d_model"] == CFG.d_model
    assert manifest["layer_weight_names"] == list(M.LAYER_WEIGHTS)


def test_manifest_covers_all_variants(manifest):
    arts = manifest["artifacts"]
    for c in CFG.chunk_sizes:
        assert f"layer_prefill_c{c}" in arts
        assert f"embed_n{c}" in arts
    for b in CFG.batch_sizes:
        assert f"layer_decode_b{b}" in arts
        assert f"head_b{b}" in arts
        assert f"embed_n{b}" in arts


def test_artifact_files_exist_and_parse(art_dir, manifest):
    for name, meta in manifest["artifacts"].items():
        path = art_dir / meta["file"]
        assert path.exists(), name
        text = path.read_text()
        # Basic HLO-text sanity: module header and an entry computation.
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_artifact_arg_specs(manifest):
    a = manifest["artifacts"][f"layer_prefill_c{CFG.chunk_sizes[0]}"]
    # x, k, v, pos + 9 weights
    assert len(a["args"]) == 13
    assert a["args"][0]["shape"] == [CFG.chunk_sizes[0], CFG.d_model]
    assert a["args"][3]["dtype"] == "int32"
    assert a["kind"] == "layer_prefill"


def test_weights_npz_is_stored_zip(art_dir):
    """The xla crate's npz reader needs ZIP_STORED members."""
    with zipfile.ZipFile(art_dir / "weights.npz") as z:
        for info in z.infolist():
            assert info.compress_type == zipfile.ZIP_STORED


def test_weights_roundtrip(art_dir):
    params = M.init_params(CFG, seed=0)
    loaded = np.load(art_dir / "weights.npz")
    assert set(loaded.files) == set(params.keys())
    np.testing.assert_allclose(loaded["l0.wq"], params["l0.wq"], rtol=0, atol=0)
    np.testing.assert_allclose(loaded["emb"], params["emb"], rtol=0, atol=0)


def test_golden_replays(art_dir):
    """Golden generations must reproduce when re-run from the same seed."""
    cases = json.loads((art_dir / "golden.json").read_text())
    assert len(cases) >= 2
    params = M.init_params(CFG, seed=0)
    case = cases[-1]  # the shortest prompt — cheapest to replay
    h, kc, vc = M.prefill_chunked(CFG, params, case["prompt"], case["chunk"])
    out = M.decode_steps(CFG, params, h, kc, vc,
                         start_pos=len(case["prompt"]),
                         steps=len(case["generated"]))
    assert out == case["generated"]


def test_golden_prompts_in_vocab(art_dir):
    cases = json.loads((art_dir / "golden.json").read_text())
    for case in cases:
        assert all(0 <= t < CFG.vocab for t in case["prompt"])
        assert all(0 <= t < CFG.vocab for t in case["generated"])


def test_hlo_text_has_tuple_root(art_dir, manifest):
    """return_tuple=True so Rust can uniformly decompose outputs."""
    meta = manifest["artifacts"]["head_b1"]
    text = (art_dir / meta["file"]).read_text()
    # The entry computation must end in a tuple(...) root instruction.
    entry = text[text.index("ENTRY"):]
    assert "tuple(" in entry, entry[:400]
