"""L2 correctness: chunked/batched model pipelines on the tiny config.

These are the invariants the Rust coordinator relies on when it splits a
prompt into elastic chunks, pads margins, batches decodes, and resumes
preempted requests from KV-cache checkpoints.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import CONFIGS
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = CONFIGS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def _prompt(n, seed=1):
    return [int(t) for t in np.random.default_rng(seed).integers(0, CFG.vocab, n)]


@pytest.mark.parametrize("chunk", CFG.chunk_sizes)
@pytest.mark.parametrize("plen", [1, 5, 16, 21, 32, 47])
def test_chunked_prefill_matches_full(params, chunk, plen):
    """Chunked prefill (with padded margin) == single-shot prefill."""
    toks = _prompt(plen)
    h1, k1, v1 = M.prefill_chunked(CFG, params, toks, chunk=chunk)
    h2, k2, v2 = M.full_prefill_ref(CFG, params, toks)
    np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-4)
    # cache agreement on the *valid* prefix only (margin slots may differ)
    for a, b in zip(k1, k2):
        np.testing.assert_allclose(a[:plen], b[:plen], rtol=1e-4, atol=1e-4)
    for a, b in zip(v1, v2):
        np.testing.assert_allclose(a[:plen], b[:plen], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("plen,steps", [(21, 6), (8, 4)])
def test_decode_matches_prefill_extension(params, plen, steps):
    """Greedy decode == re-prefilling prompt+generated and re-predicting.

    This is the fundamental KV-cache soundness property: garbage written
    by padded margin chunks must never leak into later steps.
    """
    toks = _prompt(plen, seed=7)
    h, kc, vc = M.prefill_chunked(CFG, params, toks, chunk=CFG.chunk_sizes[0])
    out = M.decode_steps(CFG, params, h, kc, vc, start_pos=plen, steps=steps)
    assert len(out) == steps
    for i in range(1, steps):
        h2, _, _ = M.full_prefill_ref(CFG, params, toks + out[:i])
        tok = M.head(h2, params["final_norm"], params["emb"])
        assert int(tok[0]) == out[i], f"divergence at step {i}"


def test_different_chunk_sizes_same_generation(params):
    """The elastic-chunk choice is a scheduling decision — it must not
    change the generated tokens."""
    toks = _prompt(23, seed=3)
    outs = []
    for chunk in CFG.chunk_sizes:
        h, kc, vc = M.prefill_chunked(CFG, params, toks, chunk=chunk)
        outs.append(M.decode_steps(CFG, params, h, kc, vc, 23, 5))
    assert all(o == outs[0] for o in outs)


def test_batched_decode_matches_single(params):
    """A b=2 batched decode step must equal two independent b=1 steps."""
    fn = M.make_layer_decode(CFG)
    lp = M.layer_params(params, 0)
    d = CFG.d_model
    x = jax.random.normal(jax.random.key(5), (2, d), jnp.float32)
    kc = jax.random.normal(jax.random.key(6),
                           (2, CFG.max_seq, CFG.n_kv_heads, CFG.head_dim))
    vc = jax.random.normal(jax.random.key(7), kc.shape)
    pos = jnp.array([9, 17], jnp.int32)
    yb, kb, vb = fn(x, kc, vc, pos, *lp)
    for i in range(2):
        yi, ki, vi = fn(x[i:i + 1], kc[i:i + 1], vc[i:i + 1],
                        pos[i:i + 1], *lp)
        np.testing.assert_allclose(yb[i:i + 1], yi, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(kb[i:i + 1], ki, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(vb[i:i + 1], vi, rtol=1e-4, atol=1e-4)


def test_prefill_updates_only_chunk_slots(params):
    """A prefill chunk at pos writes cache slots [pos, pos+c) and nothing
    else — the property that makes kernel-boundary preemption checkpoints
    free (paper §6.2)."""
    fn = M.make_layer_prefill(CFG)
    lp = M.layer_params(params, 0)
    c, pos = CFG.chunk_sizes[0], 32
    x = jax.random.normal(jax.random.key(8), (c, CFG.d_model), jnp.float32)
    kc = jax.random.normal(jax.random.key(9),
                           (CFG.max_seq, CFG.n_kv_heads, CFG.head_dim))
    vc = jax.random.normal(jax.random.key(10), kc.shape)
    _, k2, v2 = fn(x, kc, vc, jnp.array([pos], jnp.int32), *lp)
    np.testing.assert_allclose(k2[:pos], kc[:pos], rtol=0, atol=0)
    np.testing.assert_allclose(k2[pos + c:], kc[pos + c:], rtol=0, atol=0)
    np.testing.assert_allclose(v2[:pos], vc[:pos], rtol=0, atol=0)
    np.testing.assert_allclose(v2[pos + c:], vc[pos + c:], rtol=0, atol=0)
    assert not np.allclose(k2[pos:pos + c], kc[pos:pos + c])


def test_head_is_deterministic(params):
    x = jax.random.normal(jax.random.key(11), (4, CFG.d_model), jnp.float32)
    t1 = M.head(x, params["final_norm"], params["emb"])
    t2 = M.head(x, params["final_norm"], params["emb"])
    assert (np.asarray(t1) == np.asarray(t2)).all()
    assert t1.dtype == jnp.int32
    assert (np.asarray(t1) >= 0).all() and (np.asarray(t1) < CFG.vocab).all()


def test_embed_shapes(params):
    toks = jnp.array([0, 1, CFG.vocab - 1], jnp.int32)
    x = M.embed(toks, params["emb"])
    assert x.shape == (3, CFG.d_model)
    np.testing.assert_allclose(x[2], params["emb"][CFG.vocab - 1])


def test_init_params_deterministic():
    p1 = M.init_params(CFG, seed=0)
    p2 = M.init_params(CFG, seed=0)
    p3 = M.init_params(CFG, seed=1)
    np.testing.assert_allclose(p1["l0.wq"], p2["l0.wq"], rtol=0, atol=0)
    assert not np.allclose(p1["l0.wq"], p3["l0.wq"])


def test_config_param_count():
    # n_params formula agrees with the actual tensor sizes
    p = M.init_params(CFG, seed=0)
    total = sum(int(np.prod(v.shape)) for v in p.values())
    assert total == CFG.n_params
