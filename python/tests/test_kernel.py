"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, cache positions, and seeds; assert_allclose is
the core correctness signal for everything the Rust engine later executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, linear, ref

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 2e-5, 2e-5


def _rand(key, shape):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# gqa_attention (prefill chunk)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    c=st.sampled_from([1, 2, 4, 8, 16]),
    qh_kh=st.sampled_from([(4, 4), (4, 2), (8, 2), (4, 1), (8, 8)]),
    hd=st.sampled_from([4, 8, 16, 32]),
    s=st.sampled_from([16, 32, 64]),
    pos_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_prefill_attention_matches_ref(c, qh_kh, hd, s, pos_frac, seed):
    qh, kh = qh_kh
    if c > s:
        c = s
    pos = int(pos_frac * (s - c))
    q = _rand(seed, (c, qh, hd))
    k = _rand(seed + 1, (s, kh, hd))
    v = _rand(seed + 2, (s, kh, hd))
    pv = jnp.array([pos], jnp.int32)
    got = attention.gqa_attention(q, k, v, pv)
    want = ref.gqa_attention_ref(q, k, v, pv)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_prefill_attention_causality():
    """Perturbing a future cache slot must not change past outputs."""
    c, qh, kh, hd, s, pos = 4, 4, 2, 8, 32, 10
    q = _rand(0, (c, qh, hd))
    k = _rand(1, (s, kh, hd))
    v = _rand(2, (s, kh, hd))
    pv = jnp.array([pos], jnp.int32)
    base = attention.gqa_attention(q, k, v, pv)
    # slot pos+c and beyond is the future for every query in the chunk
    k2 = k.at[pos + c:].set(999.0)
    v2 = v.at[pos + c:].set(-999.0)
    pert = attention.gqa_attention(q, k2, v2, pv)
    np.testing.assert_allclose(base, pert, rtol=0, atol=0)


def test_prefill_attention_within_chunk_causality():
    """Query i must ignore cache slots pos+i+1 .. pos+c-1 (later chunk rows)."""
    c, qh, kh, hd, s, pos = 8, 4, 2, 8, 32, 4
    q = _rand(3, (c, qh, hd))
    k = _rand(4, (s, kh, hd))
    v = _rand(5, (s, kh, hd))
    pv = jnp.array([pos], jnp.int32)
    base = attention.gqa_attention(q, k, v, pv)
    k2 = k.at[pos + 3:].set(7.0)  # visible only to queries i >= 3
    pert = attention.gqa_attention(q, k2, v, pv)
    np.testing.assert_allclose(base[:3], pert[:3], rtol=0, atol=0)
    assert not np.allclose(base[3:], pert[3:])


def test_prefill_attention_pos_zero_is_pure_causal():
    c, qh, kh, hd, s = 8, 4, 2, 8, 16
    q = _rand(6, (c, qh, hd))
    k = _rand(7, (s, kh, hd))
    v = _rand(8, (s, kh, hd))
    got = attention.gqa_attention(q, k, v, jnp.array([0], jnp.int32))
    want = ref.gqa_attention_ref(q, k, v, jnp.array([0], jnp.int32))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# gqa_decode_attention (batched decode)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3, 4, 8]),
    qh_kh=st.sampled_from([(4, 4), (4, 2), (8, 2), (4, 1)]),
    hd=st.sampled_from([4, 8, 16]),
    s=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
)
def test_decode_attention_matches_ref(b, qh_kh, hd, s, seed):
    qh, kh = qh_kh
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.integers(0, s, b), jnp.int32)
    q = _rand(seed, (b, qh, hd))
    k = _rand(seed + 1, (b, s, kh, hd))
    v = _rand(seed + 2, (b, s, kh, hd))
    got = attention.gqa_decode_attention(q, k, v, pos)
    want = ref.gqa_decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_decode_attention_batch_isolation():
    """Each batch lane must only read its own cache."""
    b, qh, kh, hd, s = 4, 4, 2, 8, 16
    q = _rand(0, (b, qh, hd))
    k = _rand(1, (b, s, kh, hd))
    v = _rand(2, (b, s, kh, hd))
    pos = jnp.array([3, 7, 11, 15], jnp.int32)
    base = attention.gqa_decode_attention(q, k, v, pos)
    k2 = k.at[2].set(123.0)
    pert = attention.gqa_decode_attention(q, k2, v, pos)
    for lane in (0, 1, 3):
        np.testing.assert_allclose(base[lane], pert[lane], rtol=0, atol=0)
    assert not np.allclose(base[2], pert[2])


def test_decode_attention_respects_pos_mask():
    """Cache slots beyond pos[b] (garbage/padding) must be invisible."""
    b, qh, kh, hd, s = 2, 4, 2, 8, 16
    q = _rand(3, (b, qh, hd))
    k = _rand(4, (b, s, kh, hd))
    v = _rand(5, (b, s, kh, hd))
    pos = jnp.array([5, 9], jnp.int32)
    base = attention.gqa_decode_attention(q, k, v, pos)
    k2 = k.at[:, 12:].set(1e4)
    v2 = v.at[:, 12:].set(-1e4)
    pert = attention.gqa_decode_attention(q, k2, v2, pos)
    np.testing.assert_allclose(base, pert, rtol=0, atol=0)


def test_decode_matches_prefill_c1():
    """A b=1 decode step equals a c=1 prefill chunk at the same position."""
    qh, kh, hd, s, pos = 4, 2, 8, 32, 9
    q = _rand(9, (1, qh, hd))
    k = _rand(10, (s, kh, hd))
    v = _rand(11, (s, kh, hd))
    pv = jnp.array([pos], jnp.int32)
    dec = attention.gqa_decode_attention(q, k[None], v[None], pv)
    pre = attention.gqa_attention(q, k, v, pv)
    np.testing.assert_allclose(dec, pre, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# linear / fused_swiglu
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 2, 7, 16, 32]),
    din=st.sampled_from([8, 24, 64]),
    dout=st.sampled_from([8, 40, 64, 96, 128, 132]),
    seed=st.integers(0, 2**16),
)
def test_linear_matches_ref(n, din, dout, seed):
    x = _rand(seed, (n, din))
    w = _rand(seed + 1, (din, dout))
    np.testing.assert_allclose(
        linear.linear(x, w), ref.linear_ref(x, w), rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 4, 16]),
    din=st.sampled_from([8, 32]),
    dff=st.sampled_from([16, 40, 88, 128]),
    seed=st.integers(0, 2**16),
)
def test_fused_swiglu_matches_ref(n, din, dff, seed):
    x = _rand(seed, (n, din))
    wg = _rand(seed + 1, (din, dff))
    wu = _rand(seed + 2, (din, dff))
    np.testing.assert_allclose(
        linear.fused_swiglu(x, wg, wu), ref.swiglu_ref(x, wg, wu),
        rtol=RTOL, atol=ATOL)


def test_linear_tile_picker():
    # largest divisor <= _MAX_TILE: minimizes grid trips / maximizes the
    # VMEM-resident block
    assert linear._pick_tile(704) == 352
    assert linear._pick_tile(128) == 128
    assert linear._pick_tile(256) == 256
    assert linear._pick_tile(1024) == 512
    assert linear._pick_tile(17) == 17
    assert linear._pick_tile(40) == 40


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([1, 3, 16]), d=st.sampled_from([8, 64]),
       seed=st.integers(0, 2**16))
def test_rmsnorm_unit_scale_preserves_direction(n, d, seed):
    x = _rand(seed, (n, d))
    w = jnp.ones((d,))
    y = ref.rmsnorm_ref(x, w)
    # every row is rescaled to (approximately) unit RMS
    rms = np.sqrt(np.mean(np.square(np.asarray(y)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3, atol=1e-3)


def test_rope_position_zero_identity():
    x = _rand(0, (3, 4, 8))
    y = ref.rope_ref(x, jnp.zeros((3,), jnp.int32))
    np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6)


def test_rope_preserves_norm():
    x = _rand(1, (5, 4, 8))
    y = ref.rope_ref(x, jnp.array([0, 1, 7, 100, 1000], jnp.int32))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5, atol=1e-5)


def test_rope_relative_property():
    """RoPE dot products depend only on relative distance."""
    hd = 8
    q = _rand(2, (1, 1, hd))
    k = _rand(3, (1, 1, hd))
    def dot_at(pq, pk):
        qr = ref.rope_ref(q, jnp.array([pq], jnp.int32))
        kr = ref.rope_ref(k, jnp.array([pk], jnp.int32))
        return float(jnp.sum(qr * kr))
    np.testing.assert_allclose(dot_at(5, 3), dot_at(105, 103), rtol=1e-4, atol=1e-5)
